//! Gradient compression: operators, wire formats, stage schedules, and
//! error-feedback state.
//!
//! STL-SGD shrinks communication cost by stretching the *period*; the
//! orthogonal lever — the one Liang et al.'s variance-reduced Local SGD
//! and Stich's Local SGD analysis both price as rounds x payload — is
//! shrinking the *bytes per round*. This module supplies that axis:
//!
//! * [`CompressorSpec`] — the operator menu. [`CompressorSpec::Identity`]
//!   is the exact baseline (and keeps every legacy trajectory bit-for-bit,
//!   see below); [`CompressorSpec::TopK`] keeps the `frac`-largest-
//!   magnitude coordinates in an index+value wire format (8 bytes per kept
//!   entry); [`CompressorSpec::Qsgd`] quantizes to `bits`-bit signed
//!   levels with one f32 scale per 256-value chunk and *stochastic*
//!   rounding drawn from a dedicated per-client seeded stream, so runs
//!   stay deterministic.
//! * [`CompressionSchedule`] — fixed operator, or a stagewise *anneal*
//!   that mirrors how the paper's schedule grows k per stage: compress
//!   aggressively in the early (large-step) stages and relax toward exact
//!   as the learning rate shrinks — each stage doubles the payload budget
//!   (top-k fraction / QSGD bits) until the operator becomes `Identity`.
//! * [`EfState`] + [`average_compressed`] — error-feedback composition
//!   with the dense collectives: each participant transmits
//!   `C(theta_i - reference + residual_i)`, keeps
//!   `residual_i = delta_i - C(delta_i)` for the next round it
//!   participates in, the decoded deltas are averaged by the *same*
//!   [`super::average_masked`] schedule the exact path uses, and every
//!   participant applies `reference + mean_delta`. Non-participants'
//!   residuals are frozen — not decayed, not reset — exactly like their
//!   model replicas (DESIGN.md §6).
//!
//! Wire-byte accounting is data-independent by construction (top-k keeps
//! `ceil(frac*d)` entries whatever the values; QSGD's level array has a
//! fixed bit width), which is what lets [`crate::simnet`] price a round's
//! collective *before* the averaging runs, preserving the
//! price-then-average order of the coordinator loop.
//!
//! Invariant: `Identity` routes through the exact legacy collectives and
//! is bit-for-bit identical to the pre-compression code path — enforced
//! by tests/test_compress.rs across every cluster profile.

use super::allreduce::{average_masked, Algorithm};
use crate::rng::Rng;

/// Values per QSGD scale chunk (one f32 scale each).
pub const QSGD_CHUNK: usize = 256;

/// One compression operator with its knobs.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CompressorSpec {
    /// Exact transmission (the legacy path, bit-for-bit).
    Identity,
    /// Magnitude top-k sparsification: keep `ceil(frac * d)` entries,
    /// wire format = (u32 index, f32 value) pairs. `frac` in (0, 1].
    TopK { frac: f64 },
    /// Stochastic `bits`-bit quantization with a per-chunk f32 scale
    /// (chunk = [`QSGD_CHUNK`] values). `bits` in [2, 16]: one sign bit
    /// plus `bits - 1` magnitude bits, levels in
    /// `[-(2^(bits-1)-1), 2^(bits-1)-1]`.
    Qsgd { bits: u32 },
}

impl CompressorSpec {
    pub fn is_identity(&self) -> bool {
        matches!(self, CompressorSpec::Identity)
    }

    /// Stable operator name (CSV tags, run headers).
    pub fn label(&self) -> &'static str {
        match self {
            CompressorSpec::Identity => "identity",
            CompressorSpec::TopK { .. } => "topk",
            CompressorSpec::Qsgd { .. } => "qsgd",
        }
    }

    /// Name plus knobs, for run headers and sweep logs.
    pub fn describe(&self) -> String {
        match self {
            CompressorSpec::Identity => "identity".into(),
            CompressorSpec::TopK { frac } => format!("topk(frac={frac})"),
            CompressorSpec::Qsgd { bits } => format!("qsgd(bits={bits})"),
        }
    }

    /// Entries a top-k operator keeps for a d-dim vector.
    fn topk_kept(frac: f64, d: usize) -> usize {
        ((frac * d as f64).ceil() as usize).clamp(1, d.max(1))
    }

    /// Serialized bytes of one client's compressed d-dim message. This is
    /// the *payload* the alpha-beta model and the byte ledger scale by —
    /// data-independent, so pricing can run before the values exist.
    pub fn payload_bytes(&self, d: usize) -> u64 {
        match *self {
            CompressorSpec::Identity => 4 * d as u64,
            CompressorSpec::TopK { frac } => {
                if d == 0 {
                    0
                } else {
                    8 * Self::topk_kept(frac, d) as u64
                }
            }
            CompressorSpec::Qsgd { bits } => {
                let full = d / QSGD_CHUNK;
                let rem = d % QSGD_CHUNK;
                let mut bytes = 4 * d.div_ceil(QSGD_CHUNK) as u64; // scales
                bytes += full as u64 * (QSGD_CHUNK * bits as usize).div_ceil(8) as u64;
                if rem > 0 {
                    bytes += (rem * bits as usize).div_ceil(8) as u64;
                }
                bytes
            }
        }
    }

    /// Wire payload relative to the exact 4d-byte payload (1.0 for
    /// `Identity`; top-k fractions above 0.5 exceed 1.0 — the index
    /// overhead outweighs the dropped values).
    pub fn payload_ratio(&self, d: usize) -> f64 {
        if d == 0 {
            return 1.0;
        }
        self.payload_bytes(d) as f64 / (4 * d as u64) as f64
    }

    /// Compress one delta vector. `rng` is the transmitting client's
    /// dedicated quantization stream; it is consumed only by stochastic
    /// operators (QSGD draws exactly one uniform per coordinate, whatever
    /// the values, so streams advance data-independently). Allocating
    /// wrapper over [`Self::compress_into`] — both entries run the same
    /// code, so payloads are bit-identical whichever the caller uses.
    pub fn compress(&self, delta: &[f32], rng: &mut Rng) -> Payload {
        let mut buf = PayloadBuf::new();
        self.compress_into(delta, rng, &mut buf);
        buf.into_payload()
    }

    /// Allocation-free hot-path entry: compress `delta` into the caller's
    /// reusable [`PayloadBuf`] (cleared first). The per-client buffers in
    /// [`EfState`] amortize to zero allocations per round after warmup.
    pub fn compress_into(&self, delta: &[f32], rng: &mut Rng, buf: &mut PayloadBuf) {
        match *self {
            CompressorSpec::Identity => {
                buf.kind = PayloadKind::Dense;
                buf.dense.clear();
                buf.dense.extend_from_slice(delta);
            }
            CompressorSpec::TopK { frac } => {
                let d = delta.len();
                let k = Self::topk_kept(frac, d).min(d);
                let PayloadBuf {
                    ref mut order,
                    ref mut idx,
                    ref mut val,
                    ..
                } = *buf;
                order.clear();
                order.extend(0..d as u32);
                // Largest magnitude first; ties broken by lower index.
                // The comparator is a total order, so the selected *set*
                // is deterministic whatever partition path the O(d)
                // selection takes — this runs per participant per round,
                // so no full O(d log d) sort.
                if k < d {
                    order.select_nth_unstable_by(k - 1, |&a, &b| {
                        delta[b as usize]
                            .abs()
                            .total_cmp(&delta[a as usize].abs())
                            .then(a.cmp(&b))
                    });
                }
                idx.clear();
                idx.extend_from_slice(&order[..k]);
                idx.sort_unstable(); // ascending-index wire format
                val.clear();
                val.extend(idx.iter().map(|&i| delta[i as usize]));
                buf.kind = PayloadKind::Sparse;
                buf.dim = d;
            }
            CompressorSpec::Qsgd { bits } => {
                debug_assert!((2..=16).contains(&bits), "qsgd bits out of range: {bits}");
                let max_level = (1i32 << (bits - 1)) - 1;
                let PayloadBuf {
                    ref mut scales,
                    ref mut levels,
                    ..
                } = *buf;
                scales.clear();
                levels.clear();
                for chunk in delta.chunks(QSGD_CHUNK) {
                    let max_abs = chunk.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
                    let scale = if max_abs > 0.0 {
                        max_abs / max_level as f32
                    } else {
                        0.0
                    };
                    scales.push(scale);
                    for &v in chunk {
                        // Always draw, so the stream position depends only
                        // on the coordinate count, never on the values.
                        let u = rng.uniform();
                        let q = if scale == 0.0 {
                            0
                        } else {
                            let x = (v / scale) as f64;
                            let lo = x.floor();
                            let up = u < (x - lo);
                            (lo as i32 + up as i32).clamp(-max_level, max_level)
                        };
                        levels.push(q as i16);
                    }
                }
                buf.kind = PayloadKind::Quantized;
                buf.bits = bits;
            }
        }
    }
}

/// Which wire format a [`PayloadBuf`] currently holds.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum PayloadKind {
    Dense,
    Sparse,
    Quantized,
}

/// Reusable compression scratch: the same wire formats as [`Payload`],
/// but with every backing vector owned by the buffer and recycled across
/// rounds ([`CompressorSpec::compress_into`] / [`Self::decode_into`]).
/// One lives per client inside [`EfState`].
#[derive(Clone, Debug)]
pub struct PayloadBuf {
    kind: PayloadKind,
    // Dense
    dense: Vec<f32>,
    // Sparse (top-k)
    dim: usize,
    idx: Vec<u32>,
    val: Vec<f32>,
    /// Top-k selection scratch (the index permutation select_nth runs on).
    order: Vec<u32>,
    // Quantized (QSGD)
    bits: u32,
    scales: Vec<f32>,
    levels: Vec<i16>,
}

impl Default for PayloadBuf {
    fn default() -> Self {
        Self::new()
    }
}

impl PayloadBuf {
    pub fn new() -> Self {
        Self {
            kind: PayloadKind::Dense,
            dense: Vec::new(),
            dim: 0,
            idx: Vec::new(),
            val: Vec::new(),
            order: Vec::new(),
            bits: 0,
            scales: Vec::new(),
            levels: Vec::new(),
        }
    }

    /// Serialized size on the wire (same ledger as [`Payload::wire_bytes`]).
    pub fn wire_bytes(&self) -> u64 {
        match self.kind {
            PayloadKind::Dense => 4 * self.dense.len() as u64,
            PayloadKind::Sparse => 8 * self.idx.len() as u64,
            PayloadKind::Quantized => {
                let mut bytes = 4 * self.scales.len() as u64;
                for chunk in self.levels.chunks(QSGD_CHUNK) {
                    bytes += (chunk.len() * self.bits as usize).div_ceil(8) as u64;
                }
                bytes
            }
        }
    }

    /// Dense decoded image written into `out` (overwritten; same values
    /// as [`Payload::decode`] bit-for-bit).
    pub fn decode_into(&self, out: &mut [f32]) {
        match self.kind {
            PayloadKind::Dense => out.copy_from_slice(&self.dense),
            PayloadKind::Sparse => {
                debug_assert_eq!(out.len(), self.dim);
                out.fill(0.0);
                for (&i, &v) in self.idx.iter().zip(&self.val) {
                    out[i as usize] = v;
                }
            }
            PayloadKind::Quantized => {
                for (chunk_i, chunk) in self.levels.chunks(QSGD_CHUNK).enumerate() {
                    let s = self.scales[chunk_i];
                    let base = chunk_i * QSGD_CHUNK;
                    for (j, &q) in chunk.iter().enumerate() {
                        out[base + j] = q as f32 * s;
                    }
                }
            }
        }
    }

    /// Move the buffered message into the owning [`Payload`] form (the
    /// legacy API; consumes the buffers, so only the allocating wrapper
    /// uses it).
    fn into_payload(self) -> Payload {
        match self.kind {
            PayloadKind::Dense => Payload::Dense(self.dense),
            PayloadKind::Sparse => Payload::Sparse {
                dim: self.dim,
                idx: self.idx,
                val: self.val,
            },
            PayloadKind::Quantized => Payload::Quantized {
                bits: self.bits,
                scales: self.scales,
                levels: self.levels,
            },
        }
    }
}

/// One client's compressed message: enough structure to decode the dense
/// image and to count the serialized wire bytes honestly.
#[derive(Clone, Debug, PartialEq)]
pub enum Payload {
    /// Exact f32 vector (4 bytes/value).
    Dense(Vec<f32>),
    /// Top-k: ascending coordinate indices plus their values
    /// (4 + 4 bytes per kept entry).
    Sparse {
        dim: usize,
        idx: Vec<u32>,
        val: Vec<f32>,
    },
    /// QSGD: one f32 scale per [`QSGD_CHUNK`]-value chunk plus a
    /// `bits`-bit signed level per value (stored widened to i16; the wire
    /// count packs them at `bits` bits).
    Quantized {
        bits: u32,
        scales: Vec<f32>,
        levels: Vec<i16>,
    },
}

impl Payload {
    /// Dense decoded image (what the receiver folds into the average).
    pub fn decode(&self) -> Vec<f32> {
        match self {
            Payload::Dense(v) => v.clone(),
            Payload::Sparse { dim, idx, val } => {
                let mut out = vec![0.0f32; *dim];
                for (&i, &v) in idx.iter().zip(val) {
                    out[i as usize] = v;
                }
                out
            }
            Payload::Quantized { scales, levels, .. } => levels
                .chunks(QSGD_CHUNK)
                .zip(scales)
                .flat_map(|(chunk, &s)| chunk.iter().map(move |&q| q as f32 * s))
                .collect(),
        }
    }

    /// Serialized size on the wire.
    pub fn wire_bytes(&self) -> u64 {
        match self {
            Payload::Dense(v) => 4 * v.len() as u64,
            Payload::Sparse { idx, .. } => 8 * idx.len() as u64,
            Payload::Quantized {
                bits,
                scales,
                levels,
            } => {
                let mut bytes = 4 * scales.len() as u64;
                for chunk in levels.chunks(QSGD_CHUNK) {
                    bytes += (chunk.len() * *bits as usize).div_ceil(8) as u64;
                }
                bytes
            }
        }
    }
}

/// How the operator varies over the run's stages.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum CompressionSchedule {
    /// The same operator every round.
    Fixed(CompressorSpec),
    /// Aggressive early, exact late — the byte-axis mirror of the
    /// stagewise period rule: stage s uses the base operator with its
    /// payload budget doubled s-1 times (top-k fraction, QSGD bits),
    /// becoming `Identity` at the wire-format break-even (top-k frac
    /// 0.5, where 8B/entry meets the exact 4d payload; QSGD past 16
    /// bits) — past break-even the lossy operator would cost *more*
    /// bytes than exact transmission. Single-phase algorithms (stage 0)
    /// use the base operator as-is.
    Anneal(CompressorSpec),
}

impl Default for CompressionSchedule {
    fn default() -> Self {
        CompressionSchedule::Fixed(CompressorSpec::Identity)
    }
}

impl CompressionSchedule {
    /// Parse a schedule name; knobs keep their defaults (patch them via
    /// the `topk_frac` / `compress_bits` config keys).
    pub fn parse(s: &str) -> Option<CompressionSchedule> {
        match s {
            "identity" => Some(CompressionSchedule::Fixed(CompressorSpec::Identity)),
            "topk" => Some(CompressionSchedule::Fixed(CompressorSpec::TopK { frac: 0.1 })),
            "qsgd" => Some(CompressionSchedule::Fixed(CompressorSpec::Qsgd { bits: 4 })),
            "topk-anneal" => {
                Some(CompressionSchedule::Anneal(CompressorSpec::TopK { frac: 0.1 }))
            }
            "qsgd-anneal" => Some(CompressionSchedule::Anneal(CompressorSpec::Qsgd { bits: 4 })),
            _ => None,
        }
    }

    /// Stable textual name; [`Self::parse`] round-trips it (knobs aside).
    pub fn label(&self) -> &'static str {
        match self {
            CompressionSchedule::Fixed(CompressorSpec::Identity)
            | CompressionSchedule::Anneal(CompressorSpec::Identity) => "identity",
            CompressionSchedule::Fixed(CompressorSpec::TopK { .. }) => "topk",
            CompressionSchedule::Fixed(CompressorSpec::Qsgd { .. }) => "qsgd",
            CompressionSchedule::Anneal(CompressorSpec::TopK { .. }) => "topk-anneal",
            CompressionSchedule::Anneal(CompressorSpec::Qsgd { .. }) => "qsgd-anneal",
        }
    }

    /// Name plus knobs, for run headers and sweep logs.
    pub fn describe(&self) -> String {
        match self {
            CompressionSchedule::Fixed(s) => s.describe(),
            CompressionSchedule::Anneal(s) => format!("anneal({})", s.describe()),
        }
    }

    /// The base operator the knob keys patch.
    pub fn base(&self) -> CompressorSpec {
        match self {
            CompressionSchedule::Fixed(s) | CompressionSchedule::Anneal(s) => *s,
        }
    }

    /// True when every stage's operator is `Identity` — the coordinator
    /// then keeps the exact legacy code path (no reference tracking, no
    /// residual state), preserving trajectories bit-for-bit.
    pub fn is_always_identity(&self) -> bool {
        self.base().is_identity()
    }

    /// Patch the top-k fraction (inert unless the base operator is
    /// `TopK`, mirroring the controller-knob semantics).
    pub fn set_topk_frac(&mut self, f: f64) {
        match self {
            CompressionSchedule::Fixed(CompressorSpec::TopK { frac })
            | CompressionSchedule::Anneal(CompressorSpec::TopK { frac }) => *frac = f,
            _ => {}
        }
    }

    /// Patch the QSGD bit width (inert unless the base operator is
    /// `Qsgd`).
    pub fn set_bits(&mut self, b: u32) {
        match self {
            CompressionSchedule::Fixed(CompressorSpec::Qsgd { bits })
            | CompressionSchedule::Anneal(CompressorSpec::Qsgd { bits }) => *bits = b,
            _ => {}
        }
    }

    /// The operator in effect for a phase with the given stage index
    /// (1-based for the STL variants, 0 for single-phase algorithms —
    /// treated as the base stage).
    pub fn spec_for_stage(&self, stage: usize) -> CompressorSpec {
        match *self {
            CompressionSchedule::Fixed(s) => s,
            CompressionSchedule::Anneal(base) => {
                let relax = stage.saturating_sub(1).min(63) as i32;
                if relax == 0 {
                    // The base stage always uses the operator exactly as
                    // configured — anneal only ever *relaxes* from there.
                    return base;
                }
                match base {
                    CompressorSpec::Identity => CompressorSpec::Identity,
                    CompressorSpec::TopK { frac } => {
                        let f = frac * 2f64.powi(relax);
                        // Relaxed stages cut over at the wire-format
                        // break-even: 8 bytes per kept entry meets the
                        // exact 4d payload at frac 0.5, past which top-k
                        // is strictly dominated by exact transmission
                        // (more bytes AND lossy).
                        if f >= 0.5 {
                            CompressorSpec::Identity
                        } else {
                            CompressorSpec::TopK { frac: f }
                        }
                    }
                    CompressorSpec::Qsgd { bits } => {
                        let b = (bits as u64) << relax.min(6);
                        if b > 16 {
                            CompressorSpec::Identity
                        } else {
                            CompressorSpec::Qsgd { bits: b as u32 }
                        }
                    }
                }
            }
        }
    }
}

/// Per-client error-feedback state: the residual each client accumulates
/// (what its compressor dropped, re-injected into its next transmission),
/// its dedicated stochastic-quantization stream, and the reusable
/// compression scratch the arena hot path encodes/decodes through
/// (DESIGN.md §7: scratch is call-private, reused across rounds, never
/// aliased with model state).
pub struct EfState {
    residuals: Vec<Vec<f32>>,
    rngs: Vec<Rng>,
    /// Reusable encode/decode scratch (participants are processed one at
    /// a time, so a single scratch serves the whole fleet).
    scratch: EfScratch,
}

impl EfState {
    /// Fresh state: zero residuals, per-client streams split off a
    /// compression-dedicated root so quantization draws never perturb the
    /// sampler / simnet streams.
    pub fn new(n: usize, d: usize, seed: u64) -> Self {
        Self {
            residuals: (0..n).map(|_| vec![0.0f32; d]).collect(),
            rngs: (0..n).map(|i| ef_client_rng(seed, i)).collect(),
            scratch: EfScratch::new(d),
        }
    }

    /// Client `i`'s current residual (tests; the run loop never reads it
    /// directly).
    pub fn residual(&self, i: usize) -> &[f32] {
        &self.residuals[i]
    }

    /// Serialize residuals + quantization-stream positions for a
    /// checkpoint (DESIGN.md §12). The scratch is call-private, not
    /// state.
    pub fn save_state(&self, w: &mut crate::util::ckpt::CkptWriter) {
        w.tag("ef");
        w.usize(self.residuals.len());
        for res in &self.residuals {
            w.f32_slice(res);
        }
        for rng in &self.rngs {
            w.rng(rng.state());
        }
    }

    /// Inverse of [`Self::save_state`]; the state must have been built
    /// for the same fleet size.
    pub fn restore_state(&mut self, r: &mut crate::util::ckpt::CkptReader) -> anyhow::Result<()> {
        r.expect_tag("ef")?;
        let n = r.usize()?;
        anyhow::ensure!(
            n == self.residuals.len(),
            "checkpoint EF state covers {n} clients != configured {}",
            self.residuals.len()
        );
        for res in self.residuals.iter_mut() {
            *res = r.f32_vec()?;
        }
        for rng in self.rngs.iter_mut() {
            let (s, spare) = r.rng()?;
            *rng = Rng::from_state(s, spare);
        }
        Ok(())
    }
}

/// Client `i`'s error-feedback quantization stream — the exact stream
/// [`EfState::new`] builds eagerly for the whole fleet. Split is stateless
/// in the parent, so the cohort store can materialize the identical stream
/// lazily, on a client's first compressed round (DESIGN.md §9).
pub fn ef_client_rng(seed: u64, client: usize) -> Rng {
    use crate::rng::streams;
    Rng::new(seed ^ streams::EF_ROOT_SALT).split(streams::EF_CLIENT.label(client as u64))
}

/// Reusable compression scratch shared by every participant of a round:
/// one delta row plus the wire-format buffers. Call-private in the same
/// sense as the arena's collective scratch (DESIGN.md §7) — reused across
/// rounds, never aliased with model state.
pub struct EfScratch {
    delta: Vec<f32>,
    buf: PayloadBuf,
}

impl EfScratch {
    pub fn new(d: usize) -> Self {
        Self {
            delta: vec![0.0f32; d],
            buf: PayloadBuf::new(),
        }
    }
}

/// One participant's pre-collective half of the error-feedback delta path:
/// compress the error-corrected delta `row - reference + residual`, park
/// the decoded image in `row` (for the in-place collective to average),
/// and bank what the compressor dropped back into `residual`. Shared by
/// [`average_compressed_arena`] (dense fleet) and the cohort runner
/// (sparse store), which is what makes their trajectories bit-identical
/// by construction.
pub fn ef_encode_row(
    row: &mut [f32],
    reference: &[f32],
    residual: &mut [f32],
    rng: &mut Rng,
    spec: CompressorSpec,
    scratch: &mut EfScratch,
) {
    let d = reference.len();
    debug_assert_eq!(row.len(), d);
    debug_assert_eq!(residual.len(), d);
    let EfScratch { delta, buf } = scratch;
    delta.resize(d, 0.0);
    for j in 0..d {
        delta[j] = row[j] - reference[j] + residual[j];
    }
    spec.compress_into(delta, rng, buf);
    debug_assert_eq!(buf.wire_bytes(), spec.payload_bytes(d));
    buf.decode_into(row); // row now holds the decoded delta image
    for j in 0..d {
        residual[j] = delta[j] - row[j];
    }
}

/// Post-collective half: every participant lands at
/// `reference + mean(delta)`.
pub fn ef_rebase_row(row: &mut [f32], reference: &[f32]) {
    for j in 0..reference.len() {
        row[j] += reference[j];
    }
}

/// Per-client payload cost of one compressed round (the collective-
/// schedule scaling — ring/tree hop counts — is applied by the pricing
/// layer on top of these).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WireCost {
    /// Uncompressed f32 payload bytes (4d).
    pub payload_exact: u64,
    /// Serialized compressed payload bytes.
    pub payload_wire: u64,
}

/// Compressed masked average with error feedback.
///
/// Participants (mask bit set) each compress their delta against the
/// shared `reference` (the server model both sides agreed on after the
/// last round they synced), the decoded deltas are averaged by the exact
/// same dense collective as the uncompressed path, and every participant
/// ends at `reference + mean_delta` (so participants agree bitwise, like
/// the exact path). Non-participants are untouched: neither their replica
/// nor their residual nor their quantization stream advances — a client
/// that skips ten rounds transmits the same message it would have had it
/// been repriced the moment it rejoined.
///
/// With fewer than two participants no collective runs — the replica,
/// residual, and stream are all untouched and the cost is zero, matching
/// both [`average_masked`]'s lone-participant no-op and the pricing model
/// (the engine charges a 1-participant round zero comm seconds and zero
/// wire bytes, so a lossy mutation here would be an accuracy penalty the
/// byte/time ledger never records).
///
/// `Identity` *inside* a compressed schedule (an annealed late stage)
/// still runs the delta path: the dense payload is lossless, so each
/// participant's pending residual — dropped mass parked by earlier,
/// lossier stages — is delivered in its first exact round and flushed to
/// zero, instead of being silently stranded. An all-identity schedule
/// never reaches this function at all: the coordinator keeps the legacy
/// collectives bit-for-bit (`CompressionSchedule::is_always_identity`).
pub fn average_compressed(
    models: &mut [Vec<f32>],
    reference: &[f32],
    alg: Algorithm,
    spec: CompressorSpec,
    ef: &mut EfState,
    mask: &[bool],
) -> WireCost {
    let n = models.len();
    assert_eq!(mask.len(), n, "one mask bit per replica");
    assert_eq!(ef.residuals.len(), n, "one residual per replica");
    let d = reference.len();
    let exact = WireCost {
        payload_exact: 4 * d as u64,
        payload_wire: spec.payload_bytes(d),
    };
    let idx: Vec<usize> = mask
        .iter()
        .enumerate()
        .filter_map(|(i, &b)| if b { Some(i) } else { None })
        .collect();
    if idx.len() <= 1 {
        return WireCost {
            payload_exact: 0,
            payload_wire: 0,
        };
    }
    // Compress each participant's error-corrected delta and park the
    // decoded image in its replica slot, so the ordinary dense collective
    // can average the deltas in place.
    for &i in &idx {
        assert_eq!(models[i].len(), d, "replica/reference dim mismatch");
        let residual = &mut ef.residuals[i];
        let delta: Vec<f32> = models[i]
            .iter()
            .zip(reference)
            .zip(residual.iter())
            .map(|((&t, &r), &e)| t - r + e)
            .collect();
        let payload = spec.compress(&delta, &mut ef.rngs[i]);
        debug_assert_eq!(payload.wire_bytes(), exact.payload_wire);
        let decoded = payload.decode();
        for ((e, &dl), &dc) in residual.iter_mut().zip(&delta).zip(&decoded) {
            *e = dl - dc;
        }
        models[i] = decoded;
    }
    average_masked(models, alg, mask);
    for &i in &idx {
        for (t, &r) in models[i].iter_mut().zip(reference) {
            *t += r;
        }
    }
    exact
}

/// Arena hot-path twin of [`average_compressed`]: identical semantics and
/// bit-identical results over [`crate::linalg::ModelArena`] rows, with
/// every temporary drawn from [`EfState`]'s reusable scratch (delta row,
/// wire buffers) and the collective running in place over the arena —
/// zero allocations per round after warmup. See [`average_compressed`]
/// for the error-feedback contract (frozen non-participants, lone-
/// participant no-op, identity-flushes-residuals).
pub fn average_compressed_arena(
    arena: &mut crate::linalg::ModelArena,
    reference: &[f32],
    alg: Algorithm,
    spec: CompressorSpec,
    ef: &mut EfState,
    mask: &[bool],
) -> WireCost {
    let n = arena.n_rows();
    assert_eq!(mask.len(), n, "one mask bit per replica");
    assert_eq!(ef.residuals.len(), n, "one residual per replica");
    let d = reference.len();
    assert_eq!(arena.dim(), d, "replica/reference dim mismatch");
    let exact = WireCost {
        payload_exact: 4 * d as u64,
        payload_wire: spec.payload_bytes(d),
    };
    if mask.iter().filter(|&&b| b).count() <= 1 {
        return WireCost {
            payload_exact: 0,
            payload_wire: 0,
        };
    }
    // Compress each participant's error-corrected delta and park the
    // decoded image in its arena row, so the in-place collective can
    // average the deltas directly.
    let EfState {
        residuals,
        rngs,
        scratch,
    } = ef;
    for i in 0..n {
        if !mask[i] {
            continue;
        }
        ef_encode_row(
            arena.row_mut(i),
            reference,
            &mut residuals[i],
            &mut rngs[i],
            spec,
            scratch,
        );
    }
    super::allreduce::average_arena_masked(arena, alg, mask);
    for i in 0..n {
        if !mask[i] {
            continue;
        }
        ef_rebase_row(arena.row_mut(i), reference);
    }
    exact
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rng() -> Rng {
        Rng::new(42)
    }

    fn random_vec(d: usize, seed: u64) -> Vec<f32> {
        let mut r = Rng::new(seed);
        (0..d).map(|_| r.normal_f32()).collect()
    }

    #[test]
    fn identity_payload_roundtrips_exactly() {
        let v = random_vec(37, 1);
        let p = CompressorSpec::Identity.compress(&v, &mut rng());
        assert_eq!(p.decode(), v);
        assert_eq!(p.wire_bytes(), 4 * 37);
        assert_eq!(CompressorSpec::Identity.payload_bytes(37), 4 * 37);
        assert_eq!(CompressorSpec::Identity.payload_ratio(37), 1.0);
    }

    #[test]
    fn topk_keeps_largest_magnitudes() {
        let v = vec![0.1f32, -3.0, 0.2, 2.5, -0.05, 0.0, 1.0, -1.5];
        let spec = CompressorSpec::TopK { frac: 0.5 };
        let p = spec.compress(&v, &mut rng());
        let Payload::Sparse { dim, idx, val } = &p else {
            panic!("topk must produce a sparse payload");
        };
        assert_eq!(*dim, 8);
        assert_eq!(idx, &[1, 3, 6, 7], "4 largest |v|, ascending indices");
        assert_eq!(val, &[-3.0, 2.5, 1.0, -1.5]);
        let dec = p.decode();
        assert_eq!(dec[1], -3.0);
        assert_eq!(dec[0], 0.0, "dropped entries decode to zero");
        assert_eq!(p.wire_bytes(), 4 * 8);
        assert_eq!(spec.payload_bytes(8), 32);
        assert_eq!(spec.payload_ratio(8), 1.0, "frac 0.5 breaks even at 8B/entry");
    }

    #[test]
    fn topk_tie_break_is_low_index_and_kept_count_clamps() {
        let v = vec![1.0f32; 6];
        // All magnitudes tie: the low indices win. ceil(0.34 * 6) = 3.
        let p = CompressorSpec::TopK { frac: 0.34 }.compress(&v, &mut rng());
        let Payload::Sparse { idx, .. } = &p else { panic!() };
        assert_eq!(idx, &[0, 1, 2]);
        let p = CompressorSpec::TopK { frac: 0.01 }.compress(&v, &mut rng());
        let Payload::Sparse { idx, .. } = &p else { panic!() };
        assert_eq!(idx, &[0], "kept count floors at 1");
    }

    #[test]
    fn qsgd_decode_within_one_level_and_deterministic() {
        let v = random_vec(300, 7); // spans two chunks
        let spec = CompressorSpec::Qsgd { bits: 4 };
        let mut r1 = Rng::new(9).split(1);
        let mut r2 = Rng::new(9).split(1);
        let p1 = spec.compress(&v, &mut r1);
        let p2 = spec.compress(&v, &mut r2);
        assert_eq!(p1, p2, "same stream, same payload");
        let Payload::Quantized { scales, .. } = &p1 else { panic!() };
        assert_eq!(scales.len(), 2);
        let dec = p1.decode();
        assert_eq!(dec.len(), 300);
        for (chunk_i, chunk) in v.chunks(QSGD_CHUNK).enumerate() {
            let scale = scales[chunk_i];
            for (j, &orig) in chunk.iter().enumerate() {
                let got = dec[chunk_i * QSGD_CHUNK + j];
                assert!(
                    (got - orig).abs() <= scale + 1e-7,
                    "chunk {chunk_i}[{j}]: {orig} -> {got} (scale {scale})"
                );
            }
        }
        assert_eq!(p1.wire_bytes(), spec.payload_bytes(300));
    }

    #[test]
    fn qsgd_stream_advances_data_independently() {
        // Two different inputs consume the same number of draws, so the
        // stream position after compressing either is identical.
        let spec = CompressorSpec::Qsgd { bits: 4 };
        let (a, b) = (random_vec(64, 1), vec![0.0f32; 64]);
        let mut ra = Rng::new(5);
        let mut rb = Rng::new(5);
        spec.compress(&a, &mut ra);
        spec.compress(&b, &mut rb);
        assert_eq!(ra.next_u64(), rb.next_u64());
    }

    #[test]
    fn payload_bytes_formulas() {
        // qsgd: d=300, bits=4 -> 2 scales (8B) + 256*4/8 + 44*4/8 = 8 + 128 + 22
        assert_eq!(CompressorSpec::Qsgd { bits: 4 }.payload_bytes(300), 8 + 128 + 22);
        // topk: d=100, frac=0.25 -> 25 entries * 8B
        assert_eq!(CompressorSpec::TopK { frac: 0.25 }.payload_bytes(100), 200);
        assert!(CompressorSpec::TopK { frac: 0.25 }.payload_ratio(100) == 0.5);
        assert!(CompressorSpec::Qsgd { bits: 4 }.payload_ratio(300) < 0.2);
    }

    #[test]
    fn schedule_parse_label_roundtrip() {
        for name in ["identity", "topk", "qsgd", "topk-anneal", "qsgd-anneal"] {
            let s = CompressionSchedule::parse(name).unwrap();
            assert_eq!(s.label(), name);
        }
        assert_eq!(CompressionSchedule::parse("zip"), None);
        assert!(CompressionSchedule::default().is_always_identity());
        assert!(!CompressionSchedule::parse("topk").unwrap().is_always_identity());
    }

    #[test]
    fn anneal_relaxes_to_identity() {
        let s = CompressionSchedule::Anneal(CompressorSpec::TopK { frac: 0.1 });
        assert_eq!(s.spec_for_stage(0), CompressorSpec::TopK { frac: 0.1 });
        assert_eq!(s.spec_for_stage(1), CompressorSpec::TopK { frac: 0.1 });
        assert_eq!(s.spec_for_stage(2), CompressorSpec::TopK { frac: 0.2 });
        assert_eq!(s.spec_for_stage(3), CompressorSpec::TopK { frac: 0.4 });
        // frac 0.8 would be 8B/entry * 0.8d > 4B * d: strictly worse than
        // exact on both axes, so the anneal cuts over at the 0.5
        // break-even instead.
        assert_eq!(s.spec_for_stage(4), CompressorSpec::Identity);
        assert_eq!(s.spec_for_stage(60), CompressorSpec::Identity, "no overflow");

        // A base fraction at/above break-even still compresses in its
        // base stage (the user's explicit choice, same as Fixed); only
        // the *relaxed* stages cut over to exact.
        let s = CompressionSchedule::Anneal(CompressorSpec::TopK { frac: 0.5 });
        assert_eq!(s.spec_for_stage(1), CompressorSpec::TopK { frac: 0.5 });
        assert_eq!(s.spec_for_stage(2), CompressorSpec::Identity);

        let q = CompressionSchedule::Anneal(CompressorSpec::Qsgd { bits: 4 });
        assert_eq!(q.spec_for_stage(1), CompressorSpec::Qsgd { bits: 4 });
        assert_eq!(q.spec_for_stage(2), CompressorSpec::Qsgd { bits: 8 });
        assert_eq!(q.spec_for_stage(3), CompressorSpec::Qsgd { bits: 16 });
        assert_eq!(q.spec_for_stage(4), CompressorSpec::Identity);
        assert_eq!(q.spec_for_stage(40), CompressorSpec::Identity, "no overflow");

        let fixed = CompressionSchedule::Fixed(CompressorSpec::Qsgd { bits: 4 });
        assert_eq!(fixed.spec_for_stage(9), CompressorSpec::Qsgd { bits: 4 });
    }

    #[test]
    fn schedule_knob_patching_is_kind_gated() {
        let mut s = CompressionSchedule::parse("topk").unwrap();
        s.set_topk_frac(0.25);
        assert_eq!(s.base(), CompressorSpec::TopK { frac: 0.25 });
        s.set_bits(8); // inert: not a qsgd schedule
        assert_eq!(s.base(), CompressorSpec::TopK { frac: 0.25 });
        let mut q = CompressionSchedule::parse("qsgd-anneal").unwrap();
        q.set_bits(8);
        assert_eq!(q.base(), CompressorSpec::Qsgd { bits: 8 });
    }

    fn models(n: usize, d: usize, seed: u64) -> Vec<Vec<f32>> {
        (0..n).map(|i| random_vec(d, seed * 100 + i as u64)).collect()
    }

    #[test]
    fn identity_spec_is_lossless_and_matches_the_exact_mean() {
        // Identity inside a compressed schedule runs the delta path (so a
        // pending residual can flush); with zero residuals the result is
        // the exact participant mean up to f32 re-association.
        let d = 13;
        let reference = random_vec(d, 55);
        for alg in [Algorithm::Naive, Algorithm::Ring, Algorithm::Tree] {
            let orig = models(5, d, 3);
            let mask = [true, true, false, true, true];
            let mut a = orig.clone();
            let mut b = orig.clone();
            let mut ef = EfState::new(5, d, 7);
            let spec = CompressorSpec::Identity;
            let cost = average_compressed(&mut a, &reference, alg, spec, &mut ef, &mask);
            average_masked(&mut b, alg, &mask);
            for i in 0..5 {
                if !mask[i] {
                    assert_eq!(a[i], orig[i], "{alg:?} bystander {i}");
                    continue;
                }
                for (x, y) in a[i].iter().zip(&b[i]) {
                    assert!((x - y).abs() < 1e-5, "{alg:?} client {i}: {x} vs {y}");
                }
            }
            assert_eq!(cost.payload_exact, cost.payload_wire);
            // Dense transmission drops nothing: residuals stay zero.
            assert!(ef.residual(0).iter().all(|&e| e == 0.0));
        }
    }

    #[test]
    fn identity_spec_flushes_residuals_left_by_lossier_stages() {
        // Anneal reaching an exact late stage: the first Identity round
        // delivers the dropped mass parked in the residual and zeroes it.
        let d = 8;
        let reference = vec![0.0f32; d];
        let mut m = vec![vec![0.0f32; d]; 2];
        m[0][0] = 1.0;
        m[0][1] = 0.5;
        m[1][0] = 1.0;
        m[1][1] = 0.5;
        let mut ef = EfState::new(2, d, 3);
        let lossy = CompressorSpec::TopK { frac: 0.125 }; // keep 1 of 8
        average_compressed(&mut m, &reference, Algorithm::Naive, lossy, &mut ef, &[true; 2]);
        assert_eq!(ef.residual(0)[1], 0.5, "lossy stage parked the dropped coordinate");
        let reference2 = m[0].clone();
        average_compressed(
            &mut m,
            &reference2,
            Algorithm::Naive,
            CompressorSpec::Identity,
            &mut ef,
            &[true; 2],
        );
        assert!(
            (m[0][1] - (reference2[1] + 0.5)).abs() < 1e-6,
            "identity round must deliver the residual mass: {} vs {}",
            m[0][1],
            reference2[1] + 0.5
        );
        assert!(
            ef.residual(0).iter().all(|&e| e == 0.0),
            "identity round must flush the residual"
        );
    }

    #[test]
    fn compressed_participants_agree_and_bystanders_untouched() {
        let d = 40;
        let reference = random_vec(d, 77);
        let mut m = models(4, d, 5);
        let orig = m.clone();
        let mask = [true, false, true, true];
        let mut ef = EfState::new(4, d, 11);
        let spec = CompressorSpec::TopK { frac: 0.25 };
        let cost = average_compressed(&mut m, &reference, Algorithm::Ring, spec, &mut ef, &mask);
        assert_eq!(m[1], orig[1], "bystander replica untouched");
        assert!(ef.residual(1).iter().all(|&e| e == 0.0), "bystander residual frozen");
        assert_eq!(m[0], m[2]);
        assert_eq!(m[0], m[3], "participants end bitwise-identical");
        assert_ne!(m[0], orig[0], "the average moved the participants");
        assert_eq!(cost.payload_exact, 4 * d as u64);
        assert_eq!(cost.payload_wire, spec.payload_bytes(d));
        // Error feedback holds what the compressor dropped: delta =
        // decoded + residual, coordinate by coordinate.
        let delta0: Vec<f32> = orig[0].iter().zip(&reference).map(|(&t, &r)| t - r).collect();
        let dec_plus_res: Vec<f32> = {
            // Reconstruct: residual was delta - decoded, so decoded =
            // delta - residual.
            delta0.iter().zip(ef.residual(0)).map(|(&dl, &e)| dl - e).collect()
        };
        let kept = dec_plus_res.iter().filter(|&&v| v != 0.0).count();
        assert!(kept <= CompressorSpec::topk_kept(0.25, d), "decoded image is k-sparse");
    }

    #[test]
    fn residuals_reinject_dropped_mass_next_round() {
        // Round 1 drops a coordinate; round 2's transmission includes it
        // via the residual even if the fresh delta is zero there.
        let d = 8;
        let reference = vec![0.0f32; d];
        let mut m = vec![vec![0.0f32; d]; 2];
        m[0][0] = 1.0; // big coordinate, kept
        m[0][1] = 0.5; // dropped by top-1
        m[1][0] = 1.0;
        m[1][1] = 0.5;
        let spec = CompressorSpec::TopK { frac: 0.125 }; // keep 1 of 8
        let mut ef = EfState::new(2, d, 3);
        average_compressed(&mut m, &reference, Algorithm::Naive, spec, &mut ef, &[true; 2]);
        assert_eq!(ef.residual(0)[1], 0.5, "dropped coordinate parked in the residual");
        assert_eq!(ef.residual(0)[0], 0.0);
        // No new local work: replicas stay at the averaged model, but the
        // residual alone now carries coordinate 1 into the next round.
        let reference2 = m[0].clone();
        average_compressed(&mut m, &reference2, Algorithm::Naive, spec, &mut ef, &[true; 2]);
        assert!(
            (m[0][1] - (reference2[1] + 0.5)).abs() < 1e-6,
            "residual mass delivered: {} vs {}",
            m[0][1],
            reference2[1] + 0.5
        );
        assert_eq!(ef.residual(0)[1], 0.0, "residual emptied once transmitted");
    }

    #[test]
    fn empty_mask_is_noop_with_zero_cost() {
        let reference = vec![0.0f32; 6];
        let mut m = models(3, 6, 9);
        let orig = m.clone();
        let mut ef = EfState::new(3, 6, 1);
        let cost = average_compressed(
            &mut m,
            &reference,
            Algorithm::Ring,
            CompressorSpec::Qsgd { bits: 4 },
            &mut ef,
            &[false; 3],
        );
        assert_eq!(m, orig);
        assert_eq!(cost, WireCost { payload_exact: 0, payload_wire: 0 });
    }

    #[test]
    fn single_participant_is_a_noop_like_the_exact_path() {
        // No collective runs for a lone participant (the engine prices
        // such a round at zero comm seconds and zero bytes), so the
        // replica, residual, and quantization stream must all stay
        // untouched — a lossy mutation here would be an accuracy cost
        // the ledger never records.
        let d = 16;
        let reference = vec![0.0f32; d];
        let mut m = models(3, d, 21);
        let orig = m.clone();
        let mask = [false, true, false];
        for spec in [
            CompressorSpec::TopK { frac: 0.25 },
            CompressorSpec::Qsgd { bits: 4 },
        ] {
            let mut ef = EfState::new(3, d, 5);
            let cost =
                average_compressed(&mut m, &reference, Algorithm::Ring, spec, &mut ef, &mask);
            assert_eq!(m, orig, "{spec:?}");
            assert_eq!(cost, WireCost { payload_exact: 0, payload_wire: 0 }, "{spec:?}");
            assert!(ef.residual(1).iter().all(|&e| e == 0.0), "{spec:?}");
            // The stream did not advance: the next draw equals a fresh
            // stream's first draw.
            let mut fresh = EfState::new(3, d, 5);
            assert_eq!(ef.rngs[1].next_u64(), fresh.rngs[1].next_u64(), "{spec:?}");
        }
    }

    #[test]
    fn payload_buf_reuse_matches_fresh_compress() {
        // One buffer recycled across operators and inputs produces the
        // same payloads as a fresh allocation every time.
        let mut buf = PayloadBuf::new();
        for (seed, spec) in [
            (1u64, CompressorSpec::TopK { frac: 0.3 }),
            (2, CompressorSpec::Qsgd { bits: 4 }),
            (3, CompressorSpec::Identity),
            (4, CompressorSpec::TopK { frac: 0.05 }),
            (5, CompressorSpec::Qsgd { bits: 8 }),
        ] {
            let v = random_vec(300, seed);
            let mut r1 = Rng::new(seed).split(9);
            let mut r2 = Rng::new(seed).split(9);
            spec.compress_into(&v, &mut r1, &mut buf);
            let fresh = spec.compress(&v, &mut r2);
            assert_eq!(buf.wire_bytes(), fresh.wire_bytes(), "{spec:?}");
            let mut dec = vec![0.0f32; 300];
            buf.decode_into(&mut dec);
            assert_eq!(dec, fresh.decode(), "{spec:?}");
            assert_eq!(r1.next_u64(), r2.next_u64(), "{spec:?} stream position");
        }
    }

    #[test]
    fn arena_compressed_average_matches_legacy_bitwise() {
        let d = 40;
        let reference = random_vec(d, 77);
        let mask = [true, false, true, true];
        for alg in [Algorithm::Naive, Algorithm::Ring, Algorithm::Tree] {
            for spec in [
                CompressorSpec::Identity,
                CompressorSpec::TopK { frac: 0.25 },
                CompressorSpec::Qsgd { bits: 4 },
            ] {
                let orig = models(4, d, 5);
                let mut legacy = orig.clone();
                let mut ef_a = EfState::new(4, d, 11);
                let cost_a =
                    average_compressed(&mut legacy, &reference, alg, spec, &mut ef_a, &mask);
                let mut arena = crate::linalg::ModelArena::zeros(4, d);
                for (i, m) in orig.iter().enumerate() {
                    arena.row_mut(i).copy_from_slice(m);
                }
                let mut ef_b = EfState::new(4, d, 11);
                let cost_b =
                    average_compressed_arena(&mut arena, &reference, alg, spec, &mut ef_b, &mask);
                assert_eq!(cost_a, cost_b, "{alg:?} {spec:?}");
                assert_eq!(arena.to_vecs(), legacy, "{alg:?} {spec:?}");
                for i in 0..4 {
                    assert_eq!(ef_a.residual(i), ef_b.residual(i), "{alg:?} {spec:?} client {i}");
                }
                // Streams advanced identically (participants only).
                for i in [0usize, 2, 3] {
                    assert_eq!(
                        ef_a.rngs[i].next_u64(),
                        ef_b.rngs[i].next_u64(),
                        "{alg:?} {spec:?} client {i}"
                    );
                }
            }
        }
    }

    #[test]
    fn arena_compressed_lone_participant_is_noop() {
        let d = 16;
        let reference = vec![0.0f32; d];
        let orig = models(3, d, 21);
        let mut arena = crate::linalg::ModelArena::zeros(3, d);
        for (i, m) in orig.iter().enumerate() {
            arena.row_mut(i).copy_from_slice(m);
        }
        let mut ef = EfState::new(3, d, 5);
        let cost = average_compressed_arena(
            &mut arena,
            &reference,
            Algorithm::Ring,
            CompressorSpec::Qsgd { bits: 4 },
            &mut ef,
            &[false, true, false],
        );
        assert_eq!(arena.to_vecs(), orig);
        assert_eq!(cost, WireCost { payload_exact: 0, payload_wire: 0 });
        assert!(ef.residual(1).iter().all(|&e| e == 0.0));
    }

    #[test]
    #[should_panic(expected = "one mask bit per replica")]
    fn rejects_wrong_mask_len() {
        let mut m = models(3, 4, 1);
        let mut ef = EfState::new(3, 4, 1);
        average_compressed(
            &mut m,
            &[0.0; 4],
            Algorithm::Naive,
            CompressorSpec::Identity,
            &mut ef,
            &[true; 2],
        );
    }
}

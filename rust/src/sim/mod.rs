//! Simulated cluster time model.
//!
//! The paper reports communication *rounds* (backend-independent), but its
//! motivation is wall-clock: rounds cost latency + bandwidth. This module
//! prices each collective under an alpha-beta model and accumulates a
//! simulated clock (compute + communication), which the speedup tables and
//! the ablation benches use.
//!
//! Defaults approximate the paper's testbed interconnect (PCIe/10GbE-class:
//! alpha = 50 us/hop, beta = 10 ns/byte ~= 100 MB/s effective per link) and
//! a fixed per-iteration compute cost measured from the oracle benches.
//!
//! This module is the *calibration layer*: the discrete-event simulator in
//! [`crate::simnet`] draws its absolute costs from these models and must
//! reproduce them bit-for-bit under the zero-variance `homogeneous`
//! cluster profile (tests/test_simnet.rs enforces the equivalence).

use crate::comm::Algorithm;

/// Dependency-chain hop count of the recursive-doubling collective over
/// `n` clients: `log2(n)` exchange steps at a power of two; otherwise the
/// tail fold + doubling over the pow2 core + broadcast back adds 2 hops
/// (matching the schedule `comm::allreduce::tree` actually executes).
/// Shared by the scalar model below and the per-link fabric pricer
/// ([`crate::simnet::fabric`]), so the two can never disagree on the
/// schedule shape.
pub fn tree_hops(n: usize) -> f64 {
    if n.is_power_of_two() {
        (n as u64).trailing_zeros() as f64
    } else {
        let core = ((n as u64).next_power_of_two() >> 1).trailing_zeros() as f64;
        core + 2.0
    }
}

/// Alpha-beta network cost model.
#[derive(Clone, Copy, Debug)]
pub struct NetworkModel {
    /// Per-hop latency (seconds).
    pub alpha: f64,
    /// Per-byte transfer time (seconds/byte).
    pub beta: f64,
}

impl Default for NetworkModel {
    fn default() -> Self {
        Self {
            alpha: 50e-6,
            beta: 10e-9,
        }
    }
}

impl NetworkModel {
    /// Wall-clock seconds for one average-allreduce of a d-dim f32 model
    /// across n clients (all links run in parallel; the span is the
    /// longest dependency chain).
    pub fn allreduce_seconds(&self, alg: Algorithm, n: usize, d: usize) -> f64 {
        self.allreduce_seconds_payload(alg, n, 4.0 * d as f64)
    }

    /// Like [`Self::allreduce_seconds`], but priced on the serialized
    /// per-model message size in `bytes` — the hook the gradient-
    /// compression schedules use: a top-k / QSGD payload shrinks the beta
    /// (bandwidth) term while every hop still pays alpha, so compression
    /// helps exactly where the paper's analysis says bandwidth-bound
    /// collectives live. At `bytes = 4d` this is bit-for-bit
    /// `allreduce_seconds` (the exact path never drifts).
    pub fn allreduce_seconds_payload(&self, alg: Algorithm, n: usize, bytes: f64) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let nf = n as f64;
        match alg {
            // gather then broadcast: 2 sequential full-model transfers,
            // leader link serializes N-1 incoming models.
            Algorithm::Naive => 2.0 * (self.alpha + (nf - 1.0) * bytes * self.beta),
            // 2(N-1) pipeline steps of d/N chunks.
            Algorithm::Ring => {
                2.0 * (nf - 1.0) * (self.alpha + (bytes / nf) * self.beta)
            }
            // Recursive doubling: log2(N) full-model exchange steps at a
            // power of two. A non-power-of-two N first folds the tail
            // [2^floor(log2 N), N) into the core (one exchange) and
            // broadcasts the result back out at the end (one more), so the
            // dependency chain is floor(log2 N) + 2 hops — matching the
            // schedule comm::allreduce::tree actually executes.
            Algorithm::Tree => tree_hops(n) * (self.alpha + bytes * self.beta),
        }
    }

    /// Asymmetric pricing: the reduce (uplink) leg carries `up` bytes per
    /// model and the broadcast (downlink) leg carries `down` — the hook for
    /// downlink broadcast compression, where the server's update is
    /// compressed independently of the clients' gradients. With
    /// `up == down` (bitwise) this returns `allreduce_seconds_payload`
    /// verbatim, so the symmetric path never drifts; otherwise each
    /// collective splits into its two halves:
    ///
    /// * Naive: gather at `up` + broadcast at `down` (one alpha each).
    /// * Ring: (N-1) reduce-scatter steps at `up/N` + (N-1) all-gather
    ///   steps at `down/N`.
    /// * Tree: the same hop count, each hop averaging the two directions
    ///   (recursive doubling interleaves send/recv every hop).
    pub fn updown_seconds(&self, alg: Algorithm, n: usize, up: f64, down: f64) -> f64 {
        if up.to_bits() == down.to_bits() {
            return self.allreduce_seconds_payload(alg, n, up);
        }
        if n <= 1 {
            return 0.0;
        }
        let nf = n as f64;
        match alg {
            Algorithm::Naive => {
                (self.alpha + (nf - 1.0) * up * self.beta)
                    + (self.alpha + (nf - 1.0) * down * self.beta)
            }
            Algorithm::Ring => {
                (nf - 1.0) * (self.alpha + (up / nf) * self.beta)
                    + (nf - 1.0) * (self.alpha + (down / nf) * self.beta)
            }
            Algorithm::Tree => tree_hops(n) * (self.alpha + 0.5 * (up + down) * self.beta),
        }
    }
}

/// Simulated clock accumulating compute and communication time.
#[derive(Clone, Debug, Default)]
pub struct SimClock {
    pub compute_seconds: f64,
    pub comm_seconds: f64,
}

impl SimClock {
    pub fn total(&self) -> f64 {
        self.compute_seconds + self.comm_seconds
    }

    pub fn add_compute(&mut self, s: f64) {
        self.compute_seconds += s;
    }

    pub fn add_comm(&mut self, s: f64) {
        self.comm_seconds += s;
    }
}

/// Per-iteration compute cost model: seconds for one minibatch gradient on
/// one client (all clients run in parallel, so one iteration's span is one
/// gradient). Calibrated defaults come from the bench_grad_oracle results.
#[derive(Clone, Copy, Debug)]
pub struct ComputeModel {
    /// Seconds per (batch x param) unit of gradient work.
    pub seconds_per_flop_unit: f64,
    /// Fixed per-call overhead.
    pub overhead: f64,
}

impl Default for ComputeModel {
    fn default() -> Self {
        Self {
            // ~5 GFLOP/s effective per client core with 4 flops/unit
            seconds_per_flop_unit: 1e-9,
            overhead: 5e-6,
        }
    }
}

impl ComputeModel {
    pub fn grad_seconds(&self, batch: usize, params: usize) -> f64 {
        self.overhead + self.seconds_per_flop_unit * (batch * params) as f64
    }

    /// Closed-form compute span of one communication round of `steps`
    /// local iterations: the zero-variance reference [`crate::simnet`]
    /// must reproduce bit-for-bit. Computed as the same per-step
    /// repeated-addition fold the event engine performs, so the two sides
    /// agree to the last bit rather than merely to rounding error.
    pub fn round_compute_seconds(&self, batch: usize, params: usize, steps: u64) -> f64 {
        let g = self.grad_seconds(batch, params);
        let mut span = 0.0f64;
        for _ in 0..steps {
            span += g;
        }
        span
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_beats_naive_at_scale() {
        let m = NetworkModel::default();
        let d = 1_000_000;
        let naive = m.allreduce_seconds(Algorithm::Naive, 32, d);
        let ring = m.allreduce_seconds(Algorithm::Ring, 32, d);
        assert!(ring < naive, "ring={ring} naive={naive}");
    }

    #[test]
    fn tree_beats_ring_for_tiny_models() {
        // latency-bound regime: few bytes, many hops hurt
        let m = NetworkModel::default();
        let d = 16;
        let ring = m.allreduce_seconds(Algorithm::Ring, 32, d);
        let tree = m.allreduce_seconds(Algorithm::Tree, 32, d);
        assert!(tree < ring, "tree={tree} ring={ring}");
    }

    #[test]
    fn single_client_free() {
        let m = NetworkModel::default();
        for alg in [Algorithm::Naive, Algorithm::Ring, Algorithm::Tree] {
            assert_eq!(m.allreduce_seconds(alg, 1, 100), 0.0);
        }
    }

    #[test]
    fn cost_monotone_in_size() {
        let m = NetworkModel::default();
        for alg in [Algorithm::Naive, Algorithm::Ring, Algorithm::Tree] {
            let small = m.allreduce_seconds(alg, 8, 100);
            let big = m.allreduce_seconds(alg, 8, 100_000);
            assert!(big > small);
        }
    }

    #[test]
    fn payload_pricing_matches_exact_at_4d_and_shrinks_beta_only() {
        let m = NetworkModel::default();
        for alg in [Algorithm::Naive, Algorithm::Ring, Algorithm::Tree] {
            for n in [2usize, 6, 8, 32] {
                let exact = m.allreduce_seconds(alg, n, 1000);
                let payload = m.allreduce_seconds_payload(alg, n, 4000.0);
                assert_eq!(exact.to_bits(), payload.to_bits(), "{alg:?} n={n}");
                // A quarter payload is cheaper, but not 4x cheaper: the
                // alpha (latency) term is payload-independent.
                let quarter = m.allreduce_seconds_payload(alg, n, 1000.0);
                assert!(quarter < exact, "{alg:?} n={n}");
                assert!(quarter > exact / 4.0, "{alg:?} n={n}: alpha term vanished");
            }
            assert_eq!(m.allreduce_seconds_payload(alg, 1, 4000.0), 0.0);
        }
    }

    #[test]
    fn updown_symmetric_is_bitwise_the_payload_path() {
        let m = NetworkModel::default();
        for alg in [Algorithm::Naive, Algorithm::Ring, Algorithm::Tree] {
            for n in [1usize, 2, 6, 8, 32] {
                let sym = m.allreduce_seconds_payload(alg, n, 4000.0);
                let ud = m.updown_seconds(alg, n, 4000.0, 4000.0);
                assert_eq!(sym.to_bits(), ud.to_bits(), "{alg:?} n={n}");
            }
        }
    }

    #[test]
    fn compressed_downlink_is_cheaper_but_keeps_latency() {
        let m = NetworkModel::default();
        for alg in [Algorithm::Naive, Algorithm::Ring, Algorithm::Tree] {
            for n in [2usize, 6, 8, 32] {
                let sym = m.updown_seconds(alg, n, 4000.0, 4000.0);
                let asym = m.updown_seconds(alg, n, 4000.0, 1000.0);
                assert!(asym < sym, "{alg:?} n={n}");
                // Only the downlink beta term shrinks: the asymmetric
                // cost stays above the all-compressed symmetric one.
                let both = m.updown_seconds(alg, n, 1000.0, 1000.0);
                assert!(asym > both, "{alg:?} n={n}: uplink term vanished");
            }
            assert_eq!(m.updown_seconds(alg, 1, 4000.0, 1000.0), 0.0);
        }
    }

    #[test]
    fn clock_accumulates() {
        let mut c = SimClock::default();
        c.add_compute(1.0);
        c.add_comm(0.5);
        assert_eq!(c.total(), 1.5);
    }

    #[test]
    fn compute_model_scales() {
        let cm = ComputeModel::default();
        assert!(cm.grad_seconds(64, 1000) > cm.grad_seconds(32, 1000));
        assert!(cm.grad_seconds(32, 1000) > 0.0);
    }

    #[test]
    fn tree_non_pow2_pays_fold_and_broadcast_hops() {
        // Regression: non-power-of-two recursive doubling needs
        // floor(log2 N) + 2 exchange steps (tail fold + doubling over the
        // pow2 core + broadcast back), not ceil(log2 N).
        let m = NetworkModel::default();
        let d = 1000;
        let per_hop = m.alpha + 4.0 * d as f64 * m.beta;
        for (n, hops) in [(6usize, 4.0f64), (12, 5.0), (24, 6.0)] {
            let got = m.allreduce_seconds(Algorithm::Tree, n, d);
            assert!(
                (got - hops * per_hop).abs() < 1e-15,
                "N={n}: got {got}, want {} hops",
                hops
            );
        }
        // Powers of two are unchanged: exactly log2(N) hops.
        for (n, hops) in [(8usize, 3.0f64), (16, 4.0), (32, 5.0)] {
            let got = m.allreduce_seconds(Algorithm::Tree, n, d);
            assert!((got - hops * per_hop).abs() < 1e-15, "N={n}");
        }
    }

    #[test]
    fn tree_non_pow2_costs_more_than_next_smaller_pow2() {
        let m = NetworkModel::default();
        for n in [6usize, 12, 24] {
            let pow2_below = 1usize << (usize::BITS - 1 - n.leading_zeros());
            assert!(
                m.allreduce_seconds(Algorithm::Tree, n, 100)
                    > m.allreduce_seconds(Algorithm::Tree, pow2_below, 100),
                "N={n}"
            );
        }
    }

    #[test]
    fn round_compute_matches_per_step_fold() {
        let cm = ComputeModel::default();
        let g = cm.grad_seconds(16, 1000);
        let mut fold = 0.0f64;
        for _ in 0..13 {
            fold += g;
        }
        assert_eq!(cm.round_compute_seconds(16, 1000, 13), fold);
        assert_eq!(cm.round_compute_seconds(16, 1000, 0), 0.0);
    }
}

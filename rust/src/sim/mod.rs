//! Simulated cluster time model.
//!
//! The paper reports communication *rounds* (backend-independent), but its
//! motivation is wall-clock: rounds cost latency + bandwidth. This module
//! prices each collective under an alpha-beta model and accumulates a
//! simulated clock (compute + communication), which the speedup tables and
//! the ablation benches use.
//!
//! Defaults approximate the paper's testbed interconnect (PCIe/10GbE-class:
//! alpha = 50 us/hop, beta = 10 ns/byte ~= 100 MB/s effective per link) and
//! a fixed per-iteration compute cost measured from the oracle benches.

use crate::comm::Algorithm;

/// Alpha-beta network cost model.
#[derive(Clone, Copy, Debug)]
pub struct NetworkModel {
    /// Per-hop latency (seconds).
    pub alpha: f64,
    /// Per-byte transfer time (seconds/byte).
    pub beta: f64,
}

impl Default for NetworkModel {
    fn default() -> Self {
        Self {
            alpha: 50e-6,
            beta: 10e-9,
        }
    }
}

impl NetworkModel {
    /// Wall-clock seconds for one average-allreduce of a d-dim f32 model
    /// across n clients (all links run in parallel; the span is the
    /// longest dependency chain).
    pub fn allreduce_seconds(&self, alg: Algorithm, n: usize, d: usize) -> f64 {
        if n <= 1 {
            return 0.0;
        }
        let bytes = 4.0 * d as f64;
        let nf = n as f64;
        match alg {
            // gather then broadcast: 2 sequential full-model transfers,
            // leader link serializes N-1 incoming models.
            Algorithm::Naive => 2.0 * (self.alpha + (nf - 1.0) * bytes * self.beta),
            // 2(N-1) pipeline steps of d/N chunks.
            Algorithm::Ring => {
                2.0 * (nf - 1.0) * (self.alpha + (bytes / nf) * self.beta)
            }
            // log2(N') exchange steps of the full model.
            Algorithm::Tree => {
                let hops = (n as u64).next_power_of_two().trailing_zeros() as f64;
                hops * (self.alpha + bytes * self.beta)
            }
        }
    }
}

/// Simulated clock accumulating compute and communication time.
#[derive(Clone, Debug, Default)]
pub struct SimClock {
    pub compute_seconds: f64,
    pub comm_seconds: f64,
}

impl SimClock {
    pub fn total(&self) -> f64 {
        self.compute_seconds + self.comm_seconds
    }

    pub fn add_compute(&mut self, s: f64) {
        self.compute_seconds += s;
    }

    pub fn add_comm(&mut self, s: f64) {
        self.comm_seconds += s;
    }
}

/// Per-iteration compute cost model: seconds for one minibatch gradient on
/// one client (all clients run in parallel, so one iteration's span is one
/// gradient). Calibrated defaults come from the bench_grad_oracle results.
#[derive(Clone, Copy, Debug)]
pub struct ComputeModel {
    /// Seconds per (batch x param) unit of gradient work.
    pub seconds_per_flop_unit: f64,
    /// Fixed per-call overhead.
    pub overhead: f64,
}

impl Default for ComputeModel {
    fn default() -> Self {
        Self {
            // ~5 GFLOP/s effective per client core with 4 flops/unit
            seconds_per_flop_unit: 1e-9,
            overhead: 5e-6,
        }
    }
}

impl ComputeModel {
    pub fn grad_seconds(&self, batch: usize, params: usize) -> f64 {
        self.overhead + self.seconds_per_flop_unit * (batch * params) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_beats_naive_at_scale() {
        let m = NetworkModel::default();
        let d = 1_000_000;
        let naive = m.allreduce_seconds(Algorithm::Naive, 32, d);
        let ring = m.allreduce_seconds(Algorithm::Ring, 32, d);
        assert!(ring < naive, "ring={ring} naive={naive}");
    }

    #[test]
    fn tree_beats_ring_for_tiny_models() {
        // latency-bound regime: few bytes, many hops hurt
        let m = NetworkModel::default();
        let d = 16;
        let ring = m.allreduce_seconds(Algorithm::Ring, 32, d);
        let tree = m.allreduce_seconds(Algorithm::Tree, 32, d);
        assert!(tree < ring, "tree={tree} ring={ring}");
    }

    #[test]
    fn single_client_free() {
        let m = NetworkModel::default();
        for alg in [Algorithm::Naive, Algorithm::Ring, Algorithm::Tree] {
            assert_eq!(m.allreduce_seconds(alg, 1, 100), 0.0);
        }
    }

    #[test]
    fn cost_monotone_in_size() {
        let m = NetworkModel::default();
        for alg in [Algorithm::Naive, Algorithm::Ring, Algorithm::Tree] {
            let small = m.allreduce_seconds(alg, 8, 100);
            let big = m.allreduce_seconds(alg, 8, 100_000);
            assert!(big > small);
        }
    }

    #[test]
    fn clock_accumulates() {
        let mut c = SimClock::default();
        c.add_compute(1.0);
        c.add_comm(0.5);
        assert_eq!(c.total(), 1.5);
    }

    #[test]
    fn compute_model_scales() {
        let cm = ComputeModel::default();
        assert!(cm.grad_seconds(64, 1000) > cm.grad_seconds(32, 1000));
        assert!(cm.grad_seconds(32, 1000) > 0.0);
    }
}

//! # stl-sgd — full-system reproduction of STL-SGD (AAAI 2021)
//!
//! *STL-SGD: Speeding Up Local SGD with Stagewise Communication Period*
//! (Shen, Cheng, Liu, Xu). This crate is the L3 layer of a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — the distributed-training coordinator: leader /
//!   worker event loop, the paper's stagewise communication-period
//!   controller ([`algo`]), periodic model-averaging collectives ([`comm`]),
//!   communication accounting and a latency/bandwidth network model
//!   ([`sim`]), a discrete-event heterogeneous-cluster simulator that
//!   prices every round ([`simnet`]), plus every substrate the evaluation
//!   needs (synthetic datasets, partitioners, native gradient oracles,
//!   metrics).
//! * **L2/L1 (python/compile, build-time only)** — JAX models and Pallas
//!   kernels, AOT-lowered to HLO text artifacts that [`runtime`] loads and
//!   executes through PJRT. Python never runs on the training path.
//!
//! The offline build environment provides only the `xla` crate's vendored
//! dependency closure, so the usual ecosystem crates (tokio, serde, clap,
//! criterion, proptest, rand) are replaced by from-scratch substrates:
//! [`util::json`], [`util::cli`], [`rng`], [`bench_support`], and the
//! property-test helpers in [`testing`].
//!
//! See DESIGN.md for the system inventory and the per-experiment index, and
//! EXPERIMENTS.md for paper-vs-measured results.

pub mod algo;
pub mod analysis;
pub mod bench_support;
pub mod cohort;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod decentral;
pub mod faults;
pub mod grad;
pub mod linalg;
pub mod rng;
pub mod runtime;
pub mod sim;
pub mod simnet;
pub mod testing;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;

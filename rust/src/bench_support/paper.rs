//! Paper-evaluation harness: panel definitions + runners for every table
//! and figure in the STL-SGD evaluation (Tables 1-3, Figures 1-4).
//!
//! Two scales:
//! * `Scale::Small` — same structure, reduced rows/budget; minutes on CPU.
//!   This is what `cargo bench` and the default examples run.
//! * `Scale::Paper` — the paper's row counts and client counts (a9a 32,561
//!   x 123, MNIST-subset 11,791 x 784, N = 32; cifar-like, N = 8).
//!
//! Hyperparameters follow the paper's tuning protocol, calibrated on the
//! synthetic stand-ins (EXPERIMENTS.md §Calibration).

use crate::algo::{AlgoSpec, Variant};
use crate::comm::Algorithm;
use crate::coordinator::{self, NativeCompute, RunConfig, ThreadedCompute, Trace};
use crate::data::{partition, synth, Dataset, Shard};
use crate::grad::{logreg::NativeLogreg, mlp::MlpArch, mlp::NativeMlp, Oracle};
use crate::rng::Rng;
use std::sync::Arc;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    Small,
    Paper,
}

impl Scale {
    pub fn parse(s: &str) -> Option<Scale> {
        match s {
            "small" => Some(Scale::Small),
            "paper" => Some(Scale::Paper),
            _ => None,
        }
    }
}

/// One evaluation panel (one subplot of Figure 1/2; one table column).
#[derive(Clone, Debug)]
pub struct Panel {
    pub id: String,
    /// "a9a" | "mnist" | "wide" | "deep"
    pub dataset: String,
    pub iid: bool,
    pub n_clients: usize,
    pub seed: u64,
    pub s_percent: f64,
    pub total_steps: u64,
    pub eval_every_rounds: u64,
    pub convex: bool,
}

pub const CONVEX_ALGOS: [Variant; 5] = [
    Variant::SyncSgd,
    Variant::LbSgd,
    Variant::CrPsgd,
    Variant::LocalSgd,
    Variant::StlSc,
];

pub const NONCONVEX_ALGOS: [Variant; 6] = [
    Variant::SyncSgd,
    Variant::LbSgd,
    Variant::CrPsgd,
    Variant::LocalSgd,
    Variant::StlNc2,
    Variant::StlNc1,
];

/// Figure 1 / Table 1 panels: {a9a, mnist} x {IID, Non-IID}, N = 32.
pub fn convex_panels(scale: Scale) -> Vec<Panel> {
    let (steps, n) = match scale {
        Scale::Small => (30_000u64, 8),
        Scale::Paper => (120_000, 32),
    };
    let mut out = Vec::new();
    for dataset in ["a9a", "mnist"] {
        for iid in [true, false] {
            out.push(Panel {
                id: format!("{dataset}-{}", if iid { "iid" } else { "noniid" }),
                dataset: dataset.into(),
                iid,
                n_clients: n,
                seed: 11,
                s_percent: 50.0,
                // heterogeneity slows everything down (paper's Non-IID
                // round counts are ~20-50x the IID ones) — double budget
                total_steps: if iid { steps } else { 2 * steps },
                eval_every_rounds: 5,
                convex: true,
            });
        }
    }
    out
}

/// Figure 2 / Table 2 panels: {wide, deep} x {IID, Non-IID}, N = 8.
pub fn nonconvex_panels(scale: Scale) -> Vec<Panel> {
    let steps = match scale {
        Scale::Small => 800u64, // ~50 "epochs" of 16 iters/client
        Scale::Paper => 3_200,
    };
    let mut out = Vec::new();
    for dataset in ["wide", "deep"] {
        for iid in [true, false] {
            out.push(Panel {
                id: format!("{dataset}-{}", if iid { "iid" } else { "noniid" }),
                dataset: dataset.into(),
                iid,
                n_clients: 8,
                seed: 17,
                s_percent: 0.0,
                // heterogeneity slows training; double the Non-IID budget
                total_steps: if iid { steps } else { 2 * steps },
                eval_every_rounds: 5,
                convex: false,
            });
        }
    }
    out
}

/// Dataset + oracle for a panel (native path; sizes depend on scale).
pub fn panel_workload(panel: &Panel, scale: Scale) -> (Arc<Dataset>, Arc<dyn Oracle>, Vec<f32>, f32) {
    match panel.dataset.as_str() {
        "a9a" => {
            let rows = if scale == Scale::Paper { 32_561 } else { 8_192 };
            let ds = Arc::new(synth::a9a_like(panel.seed, rows, 123));
            let lam = 1.0 / ds.len() as f32;
            let oracle: Arc<dyn Oracle> = Arc::new(NativeLogreg::new(ds.clone(), lam));
            let theta0 = vec![0.0f32; ds.dim()];
            (ds, oracle, theta0, lam)
        }
        "mnist" => {
            let rows = if scale == Scale::Paper { 11_791 } else { 4_096 };
            let ds = Arc::new(synth::mnist_like(panel.seed, rows, 784));
            let lam = 1.0 / ds.len() as f32;
            let oracle: Arc<dyn Oracle> = Arc::new(NativeLogreg::new(ds.clone(), lam));
            let theta0 = vec![0.0f32; ds.dim()];
            (ds, oracle, theta0, lam)
        }
        "wide" | "deep" => {
            let rows = if scale == Scale::Paper { 8_192 } else { 4_096 };
            let ds = Arc::new(synth::cifar_like(panel.seed, rows, 256, 10));
            let arch = if panel.dataset == "wide" {
                MlpArch {
                    d_in: 256,
                    hidden: vec![256, 128],
                    classes: 10,
                }
            } else {
                MlpArch {
                    d_in: 256,
                    hidden: vec![128, 128, 128, 128],
                    classes: 10,
                }
            };
            let theta0 = arch.init(&mut Rng::new(panel.seed ^ 0x1217));
            let oracle: Arc<dyn Oracle> = Arc::new(NativeMlp::new(ds.clone(), arch));
            (ds, oracle, theta0, 0.0)
        }
        other => panic!("unknown panel dataset {other}"),
    }
}

/// Calibrated hyperparameters per (panel, algorithm). The tuning grid
/// follows the paper (§5); chosen values are the grid points that converge
/// fastest on the synthetic stand-ins.
pub fn panel_spec(panel: &Panel, variant: Variant) -> AlgoSpec {
    let mut spec = AlgoSpec {
        variant,
        iid: panel.iid,
        ..Default::default()
    };
    if panel.convex {
        spec.batch = 32;
        spec.eta1 = 2.0;
        spec.alpha = 1e-3;
        // Tuned per the paper's grid ({100..1600} IID, {10..160} Non-IID):
        // largest k that does not sacrifice convergence on each stand-in.
        spec.k1 = match (panel.dataset.as_str(), panel.iid) {
            (_, true) => 100.0,
            ("a9a", false) => 10.0,
            (_, false) => 20.0,
        };
        spec.t1 = 1500;
        spec.big_batch = if panel.iid { 800 } else { 160 };
        spec.batch_growth = 1.01;
        spec.batch_cap = 512;
        match variant {
            Variant::StlSc => {
                spec.k1 = match (panel.dataset.as_str(), panel.iid) {
                    ("a9a", true) => 24.0,
                    (_, true) => 50.0,
                    ("a9a", false) => 4.0,
                    (_, false) => 32.0,
                };
                spec.t1 = 250;

            }
            Variant::CrPsgd => {
                spec.alpha = 0.0;
                spec.eta1 = 0.5;
            }
            _ => {}
        }
    } else {
        spec.batch = 64;
        spec.eta1 = 0.08;
        spec.alpha = 0.0;
        spec.k1 = if panel.iid { 10.0 } else { 5.0 };
        // first stage length tuned in {10, 20, 40} epochs (paper: {20,40,60})
        spec.t1 = if panel.iid { 160 } else { 640 };
        spec.big_batch = 192;
        spec.batch_growth = 1.2;
        spec.batch_cap = 256;
        spec.inv_gamma = 0.01;
    }
    spec
}

/// Run one (panel, algorithm) cell on the threaded native engine.
pub fn run_cell(panel: &Panel, variant: Variant, scale: Scale) -> Trace {
    run_cell_with_stop(panel, variant, scale, None)
}

/// Like [`run_cell`] but stops as soon as the stop rule fires (used by the
/// table regenerators, where only rounds-to-target matters — the k = 1
/// baselines would otherwise burn the full budget after reaching target).
pub fn run_cell_with_stop(
    panel: &Panel,
    variant: Variant,
    scale: Scale,
    stop: Option<coordinator::StopRule>,
) -> Trace {
    let (ds, oracle, theta0, _lam) = panel_workload(panel, scale);
    let shards = make_panel_shards(panel, &ds);
    let mut spec = panel_spec(panel, variant);
    spec.shard_size = shards[0].len();
    let phases = spec.phases(panel.total_steps);
    let cfg = RunConfig {
        n_clients: panel.n_clients,
        collective: Algorithm::Ring,
        eval_every_rounds: panel.eval_every_rounds,
        seed: panel.seed,
        eval_accuracy: !panel.convex,
        stop,
        ..Default::default()
    };
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .min(panel.n_clients);
    if workers > 1 {
        let mut engine = ThreadedCompute::new(oracle, workers);
        coordinator::run(&mut engine, &shards, &phases, &cfg, &theta0, variant.name())
    } else {
        let mut engine = NativeCompute::new(oracle);
        coordinator::run(&mut engine, &shards, &phases, &cfg, &theta0, variant.name())
    }
}

pub fn make_panel_shards(panel: &Panel, ds: &Dataset) -> Vec<Shard> {
    let mut rng = Rng::new(panel.seed ^ 0x9A87);
    if panel.iid {
        partition::iid(ds, panel.n_clients, &mut rng)
    } else {
        partition::noniid(ds, panel.n_clients, panel.s_percent, &mut rng)
    }
}

/// f(x*) for a convex panel (full-batch GD with halving; cached per panel).
pub fn panel_f_star(panel: &Panel, scale: Scale) -> f64 {
    let cache = crate::runtime::default_artifacts_dir().join(format!(
        "fstar_panel_{}_{:?}.json",
        panel.dataset, scale
    ));
    if let Ok(j) = crate::util::json::Json::parse_file(&cache) {
        if let Some(v) = j.get("f_star").and_then(|v| v.as_f64()) {
            return v;
        }
    }
    let (ds, oracle, theta0, _) = panel_workload(panel, scale);
    let all: Vec<usize> = (0..ds.len()).collect();
    let mut theta = theta0;
    let mut eta = 8.0f32;
    let mut best = oracle.full_loss(&theta);
    for _ in 0..3000 {
        let (g, _) = oracle.grad_minibatch(&theta, &all);
        let mut cand = theta.clone();
        crate::linalg::axpy(-eta, &g, &mut cand);
        let l = oracle.full_loss(&cand);
        if l <= best {
            theta = cand;
            best = l;
        } else {
            eta *= 0.5;
            if eta < 1e-7 {
                break;
            }
        }
    }
    let j = crate::util::json::Json::obj(vec![("f_star", crate::util::json::Json::num(best))]);
    let _ = std::fs::create_dir_all(cache.parent().unwrap());
    let _ = std::fs::write(&cache, j.to_string());
    best
}

/// A formatted table row: (algorithm, rounds-to-target or None, speedup).
pub type TableRow = (String, Option<u64>, f64);

/// Table 1: communication rounds to reach `gap` objective gap.
pub fn table1_panel(panel: &Panel, scale: Scale, gap: f64) -> Vec<TableRow> {
    assert!(panel.convex);
    let f_star = panel_f_star(panel, scale);
    let mut rows = Vec::new();
    let mut sync_rounds = None;
    for v in CONVEX_ALGOS {
        let stop = coordinator::StopRule {
            metric: coordinator::Metric::Loss,
            threshold: f_star + gap,
        };
        let trace = run_cell_with_stop(panel, v, scale, Some(stop));
        let r = trace.rounds_to_gap(f_star, gap);
        if v == Variant::SyncSgd {
            sync_rounds = r;
        }
        let speedup = match (sync_rounds, r) {
            (Some(s), Some(mine)) => s as f64 / mine as f64,
            _ => f64::NAN,
        };
        rows.push((v.name().to_string(), r, speedup));
    }
    rows
}

/// Table 2: communication rounds to reach `acc` training accuracy.
pub fn table2_panel(panel: &Panel, scale: Scale, acc: f64) -> Vec<TableRow> {
    assert!(!panel.convex);
    let mut rows = Vec::new();
    let mut sync_rounds = None;
    for v in NONCONVEX_ALGOS {
        let stop = coordinator::StopRule {
            metric: coordinator::Metric::Accuracy,
            threshold: acc,
        };
        let trace = run_cell_with_stop(panel, v, scale, Some(stop));
        let r = trace.rounds_to_accuracy(acc);
        if v == Variant::SyncSgd {
            sync_rounds = r;
        }
        let speedup = match (sync_rounds, r) {
            (Some(s), Some(mine)) => s as f64 / mine as f64,
            _ => f64::NAN,
        };
        rows.push((v.name().to_string(), r, speedup));
    }
    rows
}

/// Table 3 (empirical): fitted comm-complexity exponents of each schedule.
pub fn table3_exponents() -> Vec<(String, f64, f64)> {
    use crate::util::stats::power_law_exponent;
    let mut out = Vec::new();
    for (name, variant, iid) in [
        ("Local SGD (IID)", Variant::LocalSgd, true),
        ("STL-SGD sc (IID)", Variant::StlSc, true),
        ("STL-SGD sc (Non-IID)", Variant::StlSc, false),
        ("STL-SGD nc2 (IID)", Variant::StlNc2, true),
        ("STL-SGD nc2 (Non-IID)", Variant::StlNc2, false),
    ] {
        let spec = AlgoSpec {
            variant,
            k1: 8.0,
            t1: 256,
            iid,
            ..Default::default()
        };
        let ts: Vec<f64> = (4..16u32).map(|i| 256.0 * ((1u64 << i) - 1) as f64).collect();
        let rounds: Vec<f64> = ts
            .iter()
            .map(|&t| {
                spec.phases(t as u64)
                    .iter()
                    .map(|p| p.comm_rounds())
                    .sum::<u64>() as f64
            })
            .collect();
        let (p, r2) = power_law_exponent(&ts, &rounds);
        out.push((name.to_string(), p, r2));
    }
    out
}

/// Pretty-print a table in the paper's layout.
pub fn print_table(title: &str, rows: &[TableRow]) {
    println!("\n=== {title} ===");
    println!("{:<14} {:>12} {:>10}", "Algorithm", "Rounds", "Speedup");
    for (name, rounds, speedup) in rows {
        let r = rounds.map(|r| r.to_string()).unwrap_or_else(|| "-".into());
        let s = if speedup.is_nan() {
            "-".to_string()
        } else {
            format!("{speedup:.1}x")
        };
        println!("{name:<14} {r:>12} {s:>10}");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn panels_cover_paper_grid() {
        let c = convex_panels(Scale::Small);
        assert_eq!(c.len(), 4);
        assert!(c.iter().all(|p| p.convex));
        let n = nonconvex_panels(Scale::Small);
        assert_eq!(n.len(), 4);
        assert!(n.iter().all(|p| !p.convex));
    }

    #[test]
    fn panel_workloads_build() {
        for p in convex_panels(Scale::Small) {
            let (ds, oracle, theta0, lam) = panel_workload(&p, Scale::Small);
            assert_eq!(oracle.dim(), theta0.len());
            assert!(lam > 0.0);
            assert!(ds.len() > 1000);
        }
        for p in nonconvex_panels(Scale::Small) {
            let (_, oracle, theta0, _) = panel_workload(&p, Scale::Small);
            assert_eq!(oracle.dim(), theta0.len());
        }
    }

    #[test]
    fn table3_exponents_match_theory() {
        let rows = table3_exponents();
        let by_name: std::collections::BTreeMap<_, _> =
            rows.iter().map(|(n, p, _)| (n.clone(), *p)).collect();
        assert!((by_name["Local SGD (IID)"] - 1.0).abs() < 0.05);
        assert!(by_name["STL-SGD sc (IID)"] < 0.2);
        assert!((by_name["STL-SGD sc (Non-IID)"] - 0.5).abs() < 0.12);
    }
}

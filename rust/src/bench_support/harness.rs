//! Micro-benchmark harness: warmup, timed iterations, robust statistics.
//!
//! Criterion-like in spirit: each benchmark runs a closure repeatedly,
//! reports median/mean/p10/p90 wall-clock per iteration and (optionally) a
//! derived throughput. Used by every target in `rust/benches/`.

use crate::util::stats;
use std::time::Instant;

#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_s: f64,
    pub mean_s: f64,
    pub p10_s: f64,
    pub p90_s: f64,
}

impl BenchResult {
    pub fn report(&self) -> String {
        format!(
            "{:<44} {:>10} iters  median {:>12}  mean {:>12}  p10 {:>12}  p90 {:>12}",
            self.name,
            self.iters,
            fmt_time(self.median_s),
            fmt_time(self.mean_s),
            fmt_time(self.p10_s),
            fmt_time(self.p90_s),
        )
    }

    pub fn throughput(&self, units_per_iter: f64, unit: &str) -> String {
        format!(
            "{:<44} {:>14.3} {unit}/s (median)",
            self.name,
            units_per_iter / self.median_s
        )
    }
}

pub fn fmt_time(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} us", s * 1e6)
    } else if s < 1.0 {
        format!("{:.3} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

/// Benchmark driver with a global time budget.
pub struct Bencher {
    pub warmup_iters: usize,
    pub min_iters: usize,
    pub max_iters: usize,
    pub budget_s: f64,
    pub results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self {
            warmup_iters: 3,
            min_iters: 10,
            max_iters: 1000,
            budget_s: 2.0,
            results: Vec::new(),
        }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Self {
            warmup_iters: 1,
            min_iters: 3,
            max_iters: 50,
            budget_s: 0.5,
            ..Default::default()
        }
    }

    /// Time `f` and record the result; returns per-iteration medians.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> BenchResult {
        for _ in 0..self.warmup_iters {
            f();
        }
        let mut samples = Vec::new();
        let start = Instant::now();
        while samples.len() < self.min_iters
            || (samples.len() < self.max_iters && start.elapsed().as_secs_f64() < self.budget_s)
        {
            let t0 = Instant::now();
            f();
            samples.push(t0.elapsed().as_secs_f64());
        }
        let result = BenchResult {
            name: name.to_string(),
            iters: samples.len(),
            median_s: stats::median(&samples),
            mean_s: stats::mean(&samples),
            p10_s: stats::quantile(&samples, 0.1),
            p90_s: stats::quantile(&samples, 0.9),
        };
        println!("{}", result.report());
        self.results.push(result.clone());
        result
    }
}

/// One-shot convenience.
pub fn bench<F: FnMut()>(name: &str, f: F) -> BenchResult {
    Bencher::default().run(name, f)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let mut b = Bencher::quick();
        let r = b.run("spin", || {
            let mut x = 0u64;
            for i in 0..10_000 {
                x = x.wrapping_add(i);
            }
            std::hint::black_box(x);
        });
        assert!(r.median_s > 0.0);
        assert!(r.iters >= 3);
        assert!(r.p10_s <= r.median_s && r.median_s <= r.p90_s);
    }

    #[test]
    fn fmt_time_ranges() {
        assert!(fmt_time(5e-9).ends_with("ns"));
        assert!(fmt_time(5e-6).ends_with("us"));
        assert!(fmt_time(5e-3).ends_with("ms"));
        assert!(fmt_time(5.0).ends_with(" s"));
    }
}

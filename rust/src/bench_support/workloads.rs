//! Shared experiment harness: workload construction, engines, paper-default
//! hyperparameters, f* computation, and the one-call `run_experiment` used
//! by the examples, the benches and the integration tests.

use crate::algo::{AlgoSpec, Variant};
use crate::config::{ExperimentConfig, Workload};
use crate::coordinator::{self, ClientCompute, NativeCompute, RunConfig, ThreadedCompute, Trace};
use crate::data::{partition, synth, Dataset, Shard};
use crate::grad::{logreg::NativeLogreg, mlp::MlpArch, mlp::NativeMlp, Oracle};
use crate::rng::Rng;
use std::sync::Arc;

/// Everything needed to run a workload.
pub struct WorkloadSetup {
    pub dataset: Arc<Dataset>,
    /// Native oracle (None for the transformer, which is XLA-only).
    pub oracle: Option<Arc<dyn Oracle>>,
    pub arch: Option<MlpArch>,
    pub lam: f32,
    pub theta0: Vec<f32>,
}

/// MLP capacities for the two non-convex slots (must match aot.py).
pub fn mlp_arch(workload: Workload) -> MlpArch {
    match workload {
        Workload::MlpWide => MlpArch {
            d_in: 256,
            hidden: vec![256, 128],
            classes: 10,
        },
        Workload::MlpDeep => MlpArch {
            d_in: 256,
            hidden: vec![128, 128, 128, 128],
            classes: 10,
        },
        Workload::MlpTest => MlpArch {
            d_in: 16,
            hidden: vec![16],
            classes: 4,
        },
        _ => panic!("not an mlp workload"),
    }
}

/// Dataset + oracle + initial point for a workload. Deterministic in seed.
pub fn build(workload: Workload, seed: u64) -> WorkloadSetup {
    match workload {
        Workload::LogregA9a | Workload::LogregMnist | Workload::LogregTest => {
            let dataset = Arc::new(match workload {
                Workload::LogregA9a => synth::a9a_full(seed),
                Workload::LogregMnist => synth::mnist_full(seed),
                _ => synth::a9a_like(seed, 64, 16),
            });
            let lam = 1.0 / dataset.len() as f32; // paper: lambda = 1/n
            let oracle = Arc::new(NativeLogreg::new(dataset.clone(), lam));
            let theta0 = vec![0.0f32; dataset.dim()];
            WorkloadSetup {
                dataset,
                oracle: Some(oracle),
                arch: None,
                lam,
                theta0,
            }
        }
        Workload::MlpWide | Workload::MlpDeep | Workload::MlpTest => {
            let dataset = Arc::new(match workload {
                Workload::MlpTest => synth::cifar_like(seed, 64, 16, 4),
                _ => synth::cifar_full(seed),
            });
            let arch = mlp_arch(workload);
            let oracle = Arc::new(NativeMlp::new(dataset.clone(), arch.clone()));
            let theta0 = arch.init(&mut Rng::new(seed ^ 0x1217));
            WorkloadSetup {
                dataset,
                oracle: Some(oracle),
                arch: Some(arch),
                lam: 0.0,
                theta0,
            }
        }
        Workload::TfmSmall | Workload::TfmTest => {
            let (vocab, seq, rows) = if workload == Workload::TfmSmall {
                (512usize, 32usize, 1024usize)
            } else {
                (64, 16, 64)
            };
            let corpus = synth::token_corpus(seed, rows, seq + 1, vocab);
            let rows_f: Vec<Vec<f32>> = corpus
                .iter()
                .map(|s| s.iter().map(|&t| t as f32).collect())
                .collect();
            let dataset = Arc::new(Dataset {
                x: crate::linalg::Matrix::from_rows(&rows_f),
                y: vec![0.0; rows],
                classes: vocab,
                name: "token-corpus".into(),
            });
            WorkloadSetup {
                dataset,
                oracle: None,
                arch: None,
                lam: 0.0,
                theta0: Vec::new(), // sized by the XLA engine's manifest
            }
        }
    }
}

/// Transformer init: small-normal flat vector of the artifact's true dim.
pub fn tfm_theta0(p: usize, seed: u64) -> Vec<f32> {
    let mut rng = Rng::new(seed ^ 0x7F);
    (0..p).map(|_| rng.normal_f32() * 0.02).collect()
}

/// Partition per the experiment config (paper protocol).
pub fn make_shards(cfg: &ExperimentConfig, dataset: &Dataset) -> Vec<Shard> {
    let mut rng = Rng::new(cfg.seed ^ 0x9A87);
    if cfg.iid {
        partition::iid(dataset, cfg.n_clients, &mut rng)
    } else {
        partition::noniid(dataset, cfg.n_clients, cfg.s_percent, &mut rng)
    }
}

/// Build the configured engine ("native" | "threaded" | "xla").
pub fn make_engine(
    cfg: &ExperimentConfig,
    setup: &WorkloadSetup,
) -> anyhow::Result<Box<dyn ClientCompute>> {
    match cfg.engine.as_str() {
        "native" => Ok(Box::new(NativeCompute::new(
            setup
                .oracle
                .clone()
                .ok_or_else(|| anyhow::anyhow!("{:?} has no native oracle", cfg.workload))?,
        ))),
        "threaded" => {
            let workers = std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
                .min(cfg.n_clients);
            Ok(Box::new(ThreadedCompute::new(
                setup
                    .oracle
                    .clone()
                    .ok_or_else(|| anyhow::anyhow!("{:?} has no native oracle", cfg.workload))?,
                workers,
            )))
        }
        "xla" => {
            use crate::runtime::{default_artifacts_dir, Manifest, XlaCompute};
            let client = xla::PjRtClient::cpu()
                .map_err(|e| anyhow::anyhow!("PjRtClient::cpu: {e:?}"))?;
            let manifest = Manifest::load(&default_artifacts_dir())?;
            let ac = cfg.workload.artifact_config();
            let engine = match cfg.workload {
                Workload::LogregA9a | Workload::LogregMnist | Workload::LogregTest => {
                    XlaCompute::for_logreg(
                        &client,
                        &manifest,
                        ac,
                        setup.dataset.clone(),
                        setup.lam,
                    )?
                }
                Workload::MlpWide | Workload::MlpDeep | Workload::MlpTest => {
                    XlaCompute::for_mlp(&client, &manifest, ac, setup.dataset.clone())?
                }
                Workload::TfmSmall | Workload::TfmTest => XlaCompute::for_tfm(
                    &client,
                    &manifest,
                    ac,
                    setup.dataset.clone(),
                    cfg.n_clients,
                    8, // eval on 2 fixed batches: eval cost ~ 2 grad calls
                )?,
            };
            Ok(Box::new(engine))
        }
        other => anyhow::bail!("unknown engine {other}"),
    }
}

/// Run one experiment end to end.
pub fn run_experiment(cfg: &ExperimentConfig) -> anyhow::Result<Trace> {
    run_experiment_with_stop(cfg, None)
}

pub fn run_experiment_with_stop(
    cfg: &ExperimentConfig,
    stop: Option<coordinator::StopRule>,
) -> anyhow::Result<Trace> {
    let setup = build(cfg.workload, cfg.seed);
    let shards = make_shards(cfg, &setup.dataset);
    let mut engine = make_engine(cfg, &setup)?;
    let theta0 = if setup.theta0.is_empty() {
        tfm_theta0(engine.dim(), cfg.seed)
    } else {
        setup.theta0.clone()
    };
    let mut spec = cfg.algo.clone();
    spec.iid = cfg.iid;
    spec.shard_size = shards[0].len();
    let phases = spec.phases(cfg.total_steps);
    let run_cfg = RunConfig {
        n_clients: cfg.n_clients,
        collective: cfg.collective,
        profile: cfg.cluster,
        participation: cfg.participation,
        controller: cfg.controller,
        compression: cfg.compression,
        mode: cfg.mode,
        topology: cfg.topology,
        gossip_degree: cfg.gossip_degree,
        staleness_bound: cfg.staleness_bound,
        down_compression: cfg.down_compressor,
        fabric: cfg.fabric,
        overlap: cfg.overlap,
        chunk_rows: cfg.chunk_rows,
        cohort: cfg.cohort,
        cohort_budget: cfg.cohort_budget,
        faults: cfg.faults,
        retry: cfg.retry,
        quorum: cfg.quorum,
        clip_norm: cfg.clip_norm,
        checkpoint_path: cfg.checkpoint.as_ref().map(std::path::PathBuf::from),
        resume_from: cfg.resume.as_ref().map(std::path::PathBuf::from),
        timeline_detail: cfg.timeline_detail,
        eval_every_rounds: cfg.eval_every_rounds,
        stop,
        seed: cfg.seed,
        eval_accuracy: !cfg.workload.is_convex() || true,
        ..Default::default()
    };
    Ok(coordinator::run(
        engine.as_mut(),
        &shards,
        &phases,
        &run_cfg,
        &theta0,
        spec.variant.name(),
    ))
}

/// Minimizer value f(x*) for a convex workload via full-batch GD with
/// halving on non-descent. Cached in artifacts/fstar_<name>_<seed>.json.
pub fn compute_f_star(workload: Workload, seed: u64, iters: usize) -> f64 {
    let cache = crate::runtime::default_artifacts_dir()
        .join(format!("fstar_{}_{}.json", workload.name(), seed));
    if let Ok(j) = crate::util::json::Json::parse_file(&cache) {
        if let Some(v) = j.get("f_star").and_then(|v| v.as_f64()) {
            if j.get("iters").and_then(|v| v.as_usize()) == Some(iters) {
                return v;
            }
        }
    }
    let setup = build(workload, seed);
    let oracle = setup.oracle.expect("convex workload");
    let all: Vec<usize> = (0..setup.dataset.len()).collect();
    let mut theta = setup.theta0.clone();
    let mut eta = 4.0f32;
    let mut best = oracle.full_loss(&theta);
    for _ in 0..iters {
        let (g, _) = oracle.grad_minibatch(&theta, &all);
        let mut cand = theta.clone();
        crate::linalg::axpy(-eta, &g, &mut cand);
        let l = oracle.full_loss(&cand);
        if l <= best {
            theta = cand;
            best = l;
        } else {
            eta *= 0.5;
            if eta < 1e-6 {
                break;
            }
        }
    }
    let j = crate::util::json::Json::obj(vec![
        ("f_star", crate::util::json::Json::num(best)),
        ("iters", crate::util::json::Json::num(iters as f64)),
    ]);
    let _ = std::fs::create_dir_all(cache.parent().unwrap());
    let _ = std::fs::write(&cache, j.to_string());
    best
}

/// Paper-default hyperparameters per (workload, algorithm, partition) —
/// the "tuned" values used by the table/figure regenerators. Calibrated on
/// the synthetic stand-ins (EXPERIMENTS.md documents the calibration).
pub fn paper_defaults(workload: Workload, variant: Variant, iid: bool) -> AlgoSpec {
    let convex = workload.is_convex();
    let mut spec = AlgoSpec {
        variant,
        iid,
        ..Default::default()
    };
    if convex {
        // N = 32 clients, lambda = 1/n. eta1 tuned in {N, N/10, N/100}.
        spec.batch = 32;
        spec.eta1 = 3.2; // N/10
        spec.alpha = 1e-3;
        spec.k1 = if iid { 64.0 } else { 16.0 };
        spec.t1 = 2000;
        spec.big_batch = if iid { 512 } else { 160 };
        spec.batch_growth = 1.01;
        spec.batch_cap = 512;
        match variant {
            Variant::StlSc => {
                // eta_1 T_1 = 6/mu with mu ~ lambda; practical calibration
                // keeps eta1 T1 large but finite.
                spec.eta1 = 3.2;
                spec.t1 = 2000;
                spec.k1 = if iid { 16.0 } else { 8.0 };
            }
            Variant::CrPsgd => {
                spec.eta1 = 0.32;
                spec.alpha = 0.0;
            }
            Variant::SyncSgd | Variant::LbSgd | Variant::LocalSgd => {}
            _ => {}
        }
    } else {
        // N = 8 clients, B = 64, fixed lr tuned in {N/10, N/100, N/1000}.
        spec.batch = 64;
        spec.eta1 = 0.08; // N/100
        spec.alpha = 0.0; // fixed lr per the paper's non-convex protocol
        spec.k1 = if iid { 10.0 } else { 5.0 };
        spec.t1 = 320; // ~20 epochs of 16 iters
        spec.big_batch = 320;
        spec.batch_growth = 1.2;
        spec.batch_cap = 512;
        spec.inv_gamma = 0.01; // gamma = 100
        match variant {
            Variant::StlNc1 | Variant::StlNc2 => {}
            Variant::CrPsgd => {
                spec.eta1 = 0.08;
            }
            _ => {}
        }
    }
    spec
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn build_logreg_test() {
        let s = build(Workload::LogregTest, 1);
        assert_eq!(s.theta0.len(), 16);
        assert!(s.oracle.is_some());
        assert!(s.lam > 0.0);
    }

    #[test]
    fn build_mlp_test() {
        let s = build(Workload::MlpTest, 1);
        let arch = s.arch.unwrap();
        assert_eq!(arch.param_count(), s.theta0.len());
        assert_eq!(s.oracle.unwrap().dim(), arch.param_count());
    }

    #[test]
    fn build_tfm_test_dataset_rows() {
        let s = build(Workload::TfmTest, 1);
        assert_eq!(s.dataset.dim(), 17); // seq 16 + 1
        assert!(s.oracle.is_none());
    }

    #[test]
    fn deterministic_builds() {
        let a = build(Workload::MlpTest, 9);
        let b = build(Workload::MlpTest, 9);
        assert_eq!(a.theta0, b.theta0);
        assert_eq!(a.dataset.x.data, b.dataset.x.data);
    }

    #[test]
    fn run_experiment_native_smoke() {
        let mut cfg = ExperimentConfig::default();
        cfg.engine = "native".into();
        cfg.total_steps = 60;
        cfg.algo.eta1 = 0.5;
        cfg.algo.k1 = 5.0;
        cfg.algo.batch = 8;
        cfg.algo.variant = Variant::LocalSgd;
        let trace = run_experiment(&cfg).unwrap();
        assert_eq!(trace.total_iters, 60);
        assert!(trace.final_loss().is_finite());
    }

    #[test]
    fn run_experiment_honours_cluster_profile() {
        let mut cfg = ExperimentConfig::default();
        cfg.engine = "native".into();
        cfg.total_steps = 60;
        cfg.algo.eta1 = 0.5;
        cfg.algo.k1 = 5.0;
        cfg.algo.batch = 8;
        cfg.algo.variant = Variant::LocalSgd;
        let homo = run_experiment(&cfg).unwrap();
        cfg.cluster = crate::simnet::ClusterProfile::flaky_federated();
        let flaky = run_experiment(&cfg).unwrap();
        // Same trajectory (timing-only faults), different simulated cost.
        assert_eq!(homo.final_loss(), flaky.final_loss());
        assert!(flaky.clock.total() > homo.clock.total());
        assert_eq!(flaky.timeline.rounds.len() as u64, flaky.comm.rounds);
    }

    #[test]
    fn f_star_below_initial_loss_and_cached() {
        let f1 = compute_f_star(Workload::LogregTest, 1, 200);
        assert!(f1 < std::f64::consts::LN_2);
        let f2 = compute_f_star(Workload::LogregTest, 1, 200); // cache hit
        assert_eq!(f1, f2);
    }

    #[test]
    fn paper_defaults_shapes() {
        let s = paper_defaults(Workload::LogregA9a, Variant::StlSc, true);
        assert!(s.iid && s.k1 > 1.0 && s.eta1 > 0.0);
        let s = paper_defaults(Workload::MlpWide, Variant::StlNc2, false);
        assert!(!s.iid && s.alpha == 0.0 && s.inv_gamma > 0.0);
    }
}

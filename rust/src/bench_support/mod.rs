//! From-scratch micro-benchmark harness + the paper-table regeneration
//! helpers shared by `rust/benches/` and the `paper_tables`/`paper_figures`
//! examples (criterion is unavailable offline).

pub mod harness;
pub mod paper;
pub mod workloads;

pub use harness::{bench, BenchResult, Bencher};

//! Adaptive communication periods: close the loop from `simnet` into `algo`.
//!
//! STL-SGD's stagewise rule fixes the communication period *offline*: k_s
//! grows as the stage learning rate shrinks, tuned for a fleet whose round
//! cost is known in advance. The discrete-event pricer measures exactly the
//! signal that rule cannot see — how much of each round was barrier wait
//! (stragglers) and how the collective span compares to the compute span —
//! so this module defines a [`PeriodController`] that consumes that
//! per-round telemetry ([`RoundFeedback`]) and emits the period for the
//! *next* round. Stich's *Local SGD Converges Fast and Communicates Little*
//! and Qin et al.'s *The Role of Local Steps in Local SGD* both show the
//! best local-step count is regime-dependent; the controllers track the
//! regime at runtime instead of assuming it.
//!
//! Three controllers, selected by config key `controller` / CLI
//! `--controller`:
//!
//! * [`Stagewise`] (default) — replays each phase's scheduled
//!   `comm_period` untouched. Every pre-controller trajectory and simnet
//!   timeline is preserved bit-for-bit (tests/test_adaptive.rs).
//! * [`CommRatio`] — grows/shrinks k multiplicatively to hold the measured
//!   per-round comm-span/compute-span ratio near a target (knob
//!   `target_ratio`): when barriers are cheap relative to local work it
//!   relaxes back toward the schedule, when the collective dominates it
//!   stretches the period so the round amortizes it.
//! * [`BarrierAware`] — stretches k whenever the mean barrier idle time
//!   exceeds a fraction of the round span (knob `barrier_frac`): a
//!   straggler-bound round means every barrier pays the slowest machine,
//!   so sync less often; fault-free rounds decay back to the schedule.
//!
//! Determinism contract: controllers are pure state machines over the
//! feedback sequence — no RNG, no wall clock — so identical
//! `(config, seed)` pairs yield identical realized-k sequences (the
//! controllers only ever see deterministic [`crate::simnet`] output).
//! Adaptive periods stay *relative to the phase schedule*: the controller
//! keeps a multiplier on `Phase::comm_period`, floored at 1.0 (never
//! syncing more often than the paper's rule) and capped so a pathological
//! feedback stream cannot stretch a round past `cap x` the schedule.

use super::schedule::Phase;
use crate::simnet::RoundStat;

/// Per-round telemetry the coordinator feeds back from the pricing engine.
///
/// Extracted from [`RoundStat`] (which the engine returns by value even
/// under `Detail::Off`, so feedback costs nothing and never depends on the
/// timeline being recorded).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RoundFeedback {
    /// Communication round index (0-based).
    pub round: u64,
    /// Local steps actually priced into the round — the *realized* k,
    /// smaller than the commanded period when a phase boundary cut the
    /// round short.
    pub realized_k: u64,
    /// Communication period that was commanded for the round. Controllers
    /// use `realized_k < k` to recognize phase-boundary-truncated rounds,
    /// whose short compute span against a full collective is a
    /// measurement artifact rather than a network signal.
    pub k: u64,
    /// Barrier-exit minus round start: local compute plus straggler wait.
    pub compute_span: f64,
    /// Collective span (including link jitter).
    pub comm_seconds: f64,
    /// Longest time any client idled at this round's barrier.
    pub max_barrier_wait: f64,
    /// Mean barrier idle time across the round's active clients.
    pub mean_barrier_wait: f64,
    /// Clients whose replica entered the round's average.
    pub participants: usize,
    /// Fleet size.
    pub fleet: usize,
    /// Wire-over-exact payload ratio of the round's compression operator
    /// (1.0 under `identity`). The collective span above already reflects
    /// it — so `CommRatio` trades period against payload automatically —
    /// but the explicit ratio lets a controller distinguish "comm is cheap
    /// because the network is fast" from "comm is cheap because the
    /// schedule is currently compressing hard" (DESIGN.md §6).
    pub compression_ratio: f64,
    /// Mean staleness (missed rounds) of this round's contributors —
    /// non-zero only under the `bounded-staleness` execution mode
    /// (DESIGN.md §8), where a controller can trade staleness against
    /// barrier waits. Always 0.0 under `bsp` and `gossip`.
    pub staleness: f64,
    /// Collective seconds this round hid behind local compute under the
    /// chunked overlap model (DESIGN.md §11). `comm_seconds` is the
    /// *charged* span, so a controller reading `comm_ratio()` already sees
    /// overlap-credited rounds; this field lets it distinguish "comm is
    /// cheap" from "comm is hidden" (hidden comm reappears as excess if
    /// the period — and with it the compute window — shrinks). Always 0.0
    /// on the default serialized path.
    pub overlap_seconds: f64,
}

impl RoundFeedback {
    /// Build the feedback record from one priced round.
    pub fn from_stat(rt: &RoundStat, fleet: usize) -> Self {
        Self {
            round: rt.round,
            realized_k: rt.steps,
            k: rt.k,
            compute_span: rt.compute_span,
            comm_seconds: rt.comm_seconds,
            max_barrier_wait: rt.max_barrier_wait,
            mean_barrier_wait: rt.mean_barrier_wait,
            participants: rt.participants as usize,
            fleet,
            compression_ratio: rt.compression_ratio,
            staleness: 0.0,
            overlap_seconds: rt.overlap_seconds,
        }
    }

    /// Total round span (compute + collective).
    pub fn round_span(&self) -> f64 {
        self.compute_span + self.comm_seconds
    }

    /// Collective span relative to the compute span (0 when the round did
    /// no compute — an impossible round, but the ratio stays finite).
    pub fn comm_ratio(&self) -> f64 {
        if self.compute_span > 0.0 {
            self.comm_seconds / self.compute_span
        } else {
            0.0
        }
    }

    /// Fraction of the round span the *mean* client idled at the barrier
    /// (0 for a zero-length round).
    pub fn barrier_frac(&self) -> f64 {
        let span = self.round_span();
        if span > 0.0 {
            self.mean_barrier_wait / span
        } else {
            0.0
        }
    }
}

/// A communication-period controller: the coordinator asks it for the
/// upcoming round's period and feeds every completed round's telemetry
/// back before asking again.
///
/// Contract: `period` must return a value >= 1 and be a pure function of
/// the controller state and the phase; `observe` folds exactly one round
/// into that state. No RNG, no wall clock — determinism of the realized-k
/// sequence is part of the API (DESIGN.md §5).
pub trait PeriodController {
    /// Stable controller name (reports, CSV tags).
    fn name(&self) -> &'static str;

    /// Communication period for the upcoming round of `phase` (>= 1).
    fn period(&mut self, phase: &Phase) -> u64;

    /// Fold one completed round's telemetry into the controller state.
    fn observe(&mut self, fb: &RoundFeedback);

    /// The controller's cross-round state for a checkpoint (DESIGN.md
    /// §12). Every controller here is a pure state machine whose only
    /// mutable state is the multiplier on the scheduled period, so one
    /// f64 covers them all; the stateless [`Stagewise`] default (1.0)
    /// makes the pair a no-op for it.
    fn mult_state(&self) -> f64 {
        1.0
    }

    /// Restore the state saved by [`Self::mult_state`].
    fn set_mult_state(&mut self, _m: f64) {}
}

/// The paper's fixed stagewise rule: the phase schedule *is* the period.
/// Feedback is ignored; this controller exists so the adaptive machinery
/// has a bit-for-bit-identical legacy mode as its default.
#[derive(Clone, Copy, Debug, Default)]
pub struct Stagewise;

impl PeriodController for Stagewise {
    fn name(&self) -> &'static str {
        "stagewise"
    }

    fn period(&mut self, phase: &Phase) -> u64 {
        phase.comm_period.max(1)
    }

    fn observe(&mut self, _fb: &RoundFeedback) {}
}

/// Multiplier state shared by the adaptive controllers: a factor on the
/// phase's scheduled period, floored at 1.0 (never sync more often than
/// the schedule) and capped at `cap`.
#[derive(Clone, Copy, Debug)]
struct Multiplier {
    mult: f64,
    cap: f64,
}

impl Multiplier {
    fn new(cap: f64) -> Self {
        debug_assert!(cap >= 1.0);
        Self { mult: 1.0, cap }
    }

    fn grow(&mut self, factor: f64) {
        self.mult = (self.mult * factor).min(self.cap);
    }

    fn shrink(&mut self, factor: f64) {
        self.mult = (self.mult / factor).max(1.0);
    }

    fn apply(&self, phase: &Phase) -> u64 {
        let base = phase.comm_period.max(1) as f64;
        let k = (base * self.mult).round() as u64;
        k.clamp(1, (base * self.cap).ceil() as u64)
    }
}

/// Hold the measured per-round comm/compute ratio near `target`.
///
/// When `comm_seconds / compute_span` sits above the target (the
/// collective dominates the round) the period multiplier grows by `gain`;
/// when it falls below `target / band` the multiplier decays back toward
/// the schedule. The deadband `[target / band, target * band]` prevents
/// oscillation around the fixed point.
#[derive(Clone, Copy, Debug)]
pub struct CommRatio {
    target: f64,
    band: f64,
    gain: f64,
    m: Multiplier,
}

impl CommRatio {
    /// Default adaptation constants: 25% multiplicative steps, a 20%
    /// deadband, and at most 16x the scheduled period.
    pub fn new(target: f64) -> Self {
        assert!(
            target.is_finite() && target > 0.0,
            "CommRatio target must be a positive finite ratio, got {target}"
        );
        Self {
            target,
            band: 1.2,
            gain: 1.25,
            m: Multiplier::new(16.0),
        }
    }

    /// Current multiplier on the scheduled period (diagnostics).
    pub fn multiplier(&self) -> f64 {
        self.m.mult
    }
}

impl PeriodController for CommRatio {
    fn name(&self) -> &'static str {
        "comm-ratio"
    }

    fn period(&mut self, phase: &Phase) -> u64 {
        self.m.apply(phase)
    }

    fn observe(&mut self, fb: &RoundFeedback) {
        // A phase-boundary-truncated round prices a short compute span
        // against a full collective: its inflated ratio is a measurement
        // artifact, not a network signal, so it never moves the state.
        if fb.realized_k < fb.k {
            return;
        }
        let ratio = fb.comm_ratio();
        if ratio > self.target * self.band {
            self.m.grow(self.gain);
        } else if ratio < self.target / self.band {
            self.m.shrink(self.gain);
        }
    }

    fn mult_state(&self) -> f64 {
        self.m.mult
    }

    fn set_mult_state(&mut self, m: f64) {
        self.m.mult = m;
    }
}

/// Stretch the period while rounds are straggler-bound: grow the
/// multiplier whenever the mean barrier idle exceeds `frac` of the round
/// span, decay back toward the schedule otherwise.
///
/// The gains are asymmetric (grow 1.5x, decay 1.05x) on purpose: one
/// straggler-bound round is strong evidence — the whole fleet just idled
/// behind the slowest machine — while one quiet round is weak evidence,
/// since heavy-tail stragglers hit only a few percent of steps and most
/// rounds dodge them. Symmetric gains would let the quiet majority erase
/// the signal at exactly the small periods where barriers are most
/// frequent.
#[derive(Clone, Copy, Debug)]
pub struct BarrierAware {
    frac: f64,
    grow_gain: f64,
    decay_gain: f64,
    m: Multiplier,
}

impl BarrierAware {
    /// Default adaptation constants: grow 1.5x / decay 1.05x, at most 8x
    /// the scheduled period (barrier waits keep growing with k under heavy
    /// tails, so the cap — not the signal — bounds the stretch).
    pub fn new(frac: f64) -> Self {
        assert!(
            frac.is_finite() && frac > 0.0 && frac < 1.0,
            "BarrierAware fraction must be in (0, 1), got {frac}"
        );
        Self {
            frac,
            grow_gain: 1.5,
            decay_gain: 1.05,
            m: Multiplier::new(8.0),
        }
    }

    /// Current multiplier on the scheduled period (diagnostics).
    pub fn multiplier(&self) -> f64 {
        self.m.mult
    }
}

impl PeriodController for BarrierAware {
    fn name(&self) -> &'static str {
        "barrier-aware"
    }

    fn period(&mut self, phase: &Phase) -> u64 {
        self.m.apply(phase)
    }

    fn observe(&mut self, fb: &RoundFeedback) {
        // Truncated boundary rounds carry a biased wait-vs-span signal
        // (see CommRatio::observe); ignore them.
        if fb.realized_k < fb.k {
            return;
        }
        if fb.barrier_frac() > self.frac {
            self.m.grow(self.grow_gain);
        } else {
            self.m.shrink(self.decay_gain);
        }
    }

    fn mult_state(&self) -> f64 {
        self.m.mult
    }

    fn set_mult_state(&mut self, m: f64) {
        self.m.mult = m;
    }
}

/// Config-level controller selector (the `Box<dyn PeriodController>` is
/// built per run so [`crate::coordinator::run::RunConfig`] stays `Clone`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ControllerSpec {
    /// The fixed stagewise schedule (bit-for-bit legacy behaviour).
    Stagewise,
    /// [`CommRatio`] with the given target comm/compute ratio.
    CommRatio { target: f64 },
    /// [`BarrierAware`] with the given barrier-wait span fraction.
    BarrierAware { frac: f64 },
}

impl Default for ControllerSpec {
    fn default() -> Self {
        ControllerSpec::Stagewise
    }
}

impl ControllerSpec {
    /// Parse a controller name; knobs keep their defaults (patch them via
    /// the `target_ratio` / `barrier_frac` config keys).
    pub fn parse(s: &str) -> Option<ControllerSpec> {
        match s {
            "stagewise" => Some(ControllerSpec::Stagewise),
            "comm-ratio" => Some(ControllerSpec::CommRatio { target: 1.0 }),
            "barrier-aware" => Some(ControllerSpec::BarrierAware { frac: 0.05 }),
            _ => None,
        }
    }

    /// Stable textual name; [`Self::parse`] round-trips it (knobs aside).
    pub fn label(&self) -> &'static str {
        match self {
            ControllerSpec::Stagewise => "stagewise",
            ControllerSpec::CommRatio { .. } => "comm-ratio",
            ControllerSpec::BarrierAware { .. } => "barrier-aware",
        }
    }

    /// Name plus knobs, for run headers and sweep logs.
    pub fn describe(&self) -> String {
        match self {
            ControllerSpec::Stagewise => "stagewise".into(),
            ControllerSpec::CommRatio { target } => format!("comm-ratio(target={target})"),
            ControllerSpec::BarrierAware { frac } => format!("barrier-aware(frac={frac})"),
        }
    }

    /// Materialize the controller for one run.
    pub fn build(&self) -> Box<dyn PeriodController> {
        match *self {
            ControllerSpec::Stagewise => Box::new(Stagewise),
            ControllerSpec::CommRatio { target } => Box::new(CommRatio::new(target)),
            ControllerSpec::BarrierAware { frac } => Box::new(BarrierAware::new(frac)),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::algo::LrSchedule;

    fn phase(k: u64) -> Phase {
        Phase {
            stage: 1,
            steps: 100,
            comm_period: k,
            batch: 8,
            lr: LrSchedule::Const(0.1),
            reset_anchor: false,
            inv_gamma: 0.0,
        }
    }

    fn fb(realized_k: u64, compute: f64, comm: f64, mean_wait: f64) -> RoundFeedback {
        RoundFeedback {
            round: 0,
            realized_k,
            k: realized_k,
            compute_span: compute,
            comm_seconds: comm,
            max_barrier_wait: mean_wait * 2.0,
            mean_barrier_wait: mean_wait,
            participants: 4,
            fleet: 4,
            compression_ratio: 1.0,
            staleness: 0.0,
            overlap_seconds: 0.0,
        }
    }

    #[test]
    fn stagewise_replays_phase_period() {
        let mut c = Stagewise;
        assert_eq!(c.period(&phase(7)), 7);
        assert_eq!(c.period(&phase(0)), 1, "degenerate period floors at 1");
        // Feedback, however extreme, never moves it.
        c.observe(&fb(7, 1e-6, 1.0, 0.5));
        assert_eq!(c.period(&phase(7)), 7);
    }

    #[test]
    fn comm_ratio_grows_when_comm_dominates_and_caps() {
        let mut c = CommRatio::new(1.0);
        assert_eq!(c.period(&phase(10)), 10, "starts at the schedule");
        for _ in 0..64 {
            c.observe(&fb(10, 1e-4, 1e-2, 0.0)); // ratio 100 >> target
        }
        assert_eq!(c.period(&phase(10)), 160, "capped at 16x the schedule");
        assert!((c.multiplier() - 16.0).abs() < 1e-12);
    }

    #[test]
    fn comm_ratio_decays_back_to_schedule_when_compute_dominates() {
        let mut c = CommRatio::new(1.0);
        for _ in 0..8 {
            c.observe(&fb(10, 1e-4, 1e-2, 0.0));
        }
        let stretched = c.period(&phase(10));
        assert!(stretched > 10);
        for _ in 0..64 {
            c.observe(&fb(10, 1e-2, 1e-4, 0.0)); // ratio 0.01 << target
        }
        assert_eq!(c.period(&phase(10)), 10, "floored at the schedule");
        assert!((c.multiplier() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn comm_ratio_deadband_holds_steady() {
        let mut c = CommRatio::new(1.0);
        for _ in 0..32 {
            c.observe(&fb(10, 1.0, 1.1, 0.0)); // within the 20% band
        }
        assert_eq!(c.period(&phase(10)), 10);
    }

    #[test]
    fn barrier_aware_stretches_on_straggler_waits_and_caps() {
        let mut c = BarrierAware::new(0.05);
        assert_eq!(c.period(&phase(16)), 16);
        for _ in 0..64 {
            // mean wait is 30% of the span: straggler-bound.
            c.observe(&fb(16, 0.7, 0.3, 0.3));
        }
        assert_eq!(c.period(&phase(16)), 128, "capped at 8x the schedule");
    }

    #[test]
    fn barrier_aware_stays_at_schedule_without_waits() {
        let mut c = BarrierAware::new(0.05);
        for _ in 0..32 {
            c.observe(&fb(16, 0.7, 0.3, 0.0));
        }
        assert_eq!(c.period(&phase(16)), 16);
    }

    #[test]
    fn multiplier_rounds_to_nearest_period() {
        let mut c = CommRatio::new(1.0);
        c.observe(&fb(4, 1e-4, 1e-2, 0.0)); // one growth step: mult 1.25
        assert_eq!(c.period(&phase(4)), 5); // round(4 * 1.25)
        assert_eq!(c.period(&phase(2)), 3); // round(2 * 1.25) = 2.5 -> 3
        assert_eq!(c.period(&phase(1)), 1); // round(1.25) = 1
    }

    #[test]
    fn truncated_boundary_rounds_do_not_move_controllers() {
        // A commanded-40 round cut to 10 realized steps has ~4x the
        // steady-state comm ratio purely by truncation; both adaptive
        // controllers must discard it instead of growing on the artifact.
        let mut c = CommRatio::new(1.0);
        let mut f = fb(10, 1e-4, 1e-2, 0.0);
        f.k = 40;
        for _ in 0..16 {
            c.observe(&f);
        }
        assert_eq!(c.period(&phase(10)), 10);
        let mut b = BarrierAware::new(0.05);
        let mut f = fb(10, 0.7, 0.3, 0.3);
        f.k = 40;
        for _ in 0..16 {
            b.observe(&f);
        }
        assert_eq!(b.period(&phase(16)), 16);
    }

    #[test]
    fn feedback_helpers_are_div_zero_safe() {
        let z = fb(1, 0.0, 0.0, 0.0);
        assert_eq!(z.comm_ratio(), 0.0);
        assert_eq!(z.barrier_frac(), 0.0);
        assert_eq!(z.round_span(), 0.0);
        let f = fb(8, 0.5, 0.25, 0.15);
        assert!((f.round_span() - 0.75).abs() < 1e-12);
        assert!((f.comm_ratio() - 0.5).abs() < 1e-12);
        assert!((f.barrier_frac() - 0.2).abs() < 1e-12);
    }

    #[test]
    fn spec_parse_label_roundtrip_and_build_names() {
        for name in ["stagewise", "comm-ratio", "barrier-aware"] {
            let spec = ControllerSpec::parse(name).unwrap();
            assert_eq!(spec.label(), name);
            assert_eq!(spec.build().name(), name);
        }
        assert_eq!(ControllerSpec::parse("nope"), None);
        assert_eq!(ControllerSpec::default(), ControllerSpec::Stagewise);
        assert_eq!(
            ControllerSpec::CommRatio { target: 0.5 }.describe(),
            "comm-ratio(target=0.5)"
        );
    }

    #[test]
    fn mult_state_roundtrips_every_controller() {
        // Stagewise: stateless, always 1.0, restore is a no-op.
        let mut s = Stagewise;
        assert_eq!(s.mult_state(), 1.0);
        s.set_mult_state(7.0);
        assert_eq!(s.period(&phase(10)), 10);

        // Adaptive controllers: a restored twin continues bit-identically.
        let mut c = CommRatio::new(1.0);
        for _ in 0..5 {
            c.observe(&fb(10, 1e-4, 1e-2, 0.0));
        }
        let mut c2 = CommRatio::new(1.0);
        c2.set_mult_state(c.mult_state());
        assert_eq!(c2.period(&phase(10)), c.period(&phase(10)));
        c.observe(&fb(10, 1e-4, 1e-2, 0.0));
        c2.observe(&fb(10, 1e-4, 1e-2, 0.0));
        assert_eq!(c2.mult_state().to_bits(), c.mult_state().to_bits());

        let mut b = BarrierAware::new(0.05);
        for _ in 0..3 {
            b.observe(&fb(16, 0.7, 0.3, 0.3));
        }
        let mut b2 = BarrierAware::new(0.05);
        b2.set_mult_state(b.mult_state());
        assert_eq!(b2.period(&phase(16)), b.period(&phase(16)));
    }

    #[test]
    #[should_panic(expected = "positive finite ratio")]
    fn comm_ratio_rejects_non_positive_target() {
        let _ = CommRatio::new(0.0);
    }

    #[test]
    #[should_panic(expected = "must be in (0, 1)")]
    fn barrier_aware_rejects_out_of_range_fraction() {
        let _ = BarrierAware::new(1.5);
    }
}

//! Algorithm specifications: each paper algorithm materialized as phases.
//!
//! Parameter conventions follow the paper's experiment section (§5):
//! * SyncSGD / LB-SGD / Local SGD use eta_t = eta1/(1 + alpha t) in the
//!   convex track and a fixed lr in the non-convex track;
//! * CR-PSGD grows the batch B <- rho_b * B once per epoch, capped;
//! * STL-SGD^sc (Algorithm 2): eta_{s+1} = eta_s/2, T_{s+1} = 2 T_s,
//!   k_{s+1} = 2 k_s (IID) or sqrt(2) k_s (Non-IID);
//! * STL-SGD^nc Option 1 (Algorithm 3): same schedule + prox objective;
//! * STL-SGD^nc Option 2: eta_s = eta1/s, T_s = s T1, k_s = s k1 (IID) or
//!   sqrt(s) k1 (Non-IID) + prox objective.
//!
//! k is tracked as a real number and materialized per stage as
//! max(floor(k_s), 1), exactly as Algorithm 2 line 2 specifies.

use super::schedule::{LrSchedule, Phase};

/// Which paper algorithm to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Variant {
    SyncSgd,
    LbSgd,
    CrPsgd,
    LocalSgd,
    /// STL-SGD^sc (Algorithm 2).
    StlSc,
    /// STL-SGD^nc with Option 1 (geometric schedule + prox).
    StlNc1,
    /// STL-SGD^nc with Option 2 (linear schedule + prox).
    StlNc2,
}

impl Variant {
    pub fn parse(s: &str) -> Option<Variant> {
        match s {
            "sync" | "syncsgd" => Some(Variant::SyncSgd),
            "lb" | "lbsgd" => Some(Variant::LbSgd),
            "crpsgd" | "cr" => Some(Variant::CrPsgd),
            "local" | "localsgd" => Some(Variant::LocalSgd),
            "stl-sc" | "stlsc" => Some(Variant::StlSc),
            "stl-nc1" | "stlnc1" => Some(Variant::StlNc1),
            "stl-nc2" | "stlnc2" => Some(Variant::StlNc2),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            Variant::SyncSgd => "SyncSGD",
            Variant::LbSgd => "LB-SGD",
            Variant::CrPsgd => "CR-PSGD",
            Variant::LocalSgd => "Local-SGD",
            Variant::StlSc => "STL-SGD^sc",
            Variant::StlNc1 => "STL-SGD^nc-1",
            Variant::StlNc2 => "STL-SGD^nc-2",
        }
    }

    pub fn uses_prox(&self) -> bool {
        matches!(self, Variant::StlNc1 | Variant::StlNc2)
    }
}

/// Full algorithm configuration; [`AlgoSpec::phases`] materializes the
/// phase list for a given total iteration budget.
#[derive(Clone, Debug)]
pub struct AlgoSpec {
    pub variant: Variant,
    /// Initial learning rate eta_1.
    pub eta1: f64,
    /// alpha for the InvTime schedule (baselines, convex track). When 0 the
    /// baselines use a constant lr (the paper's non-convex setting).
    pub alpha: f64,
    /// Initial communication period k_1 (k for LocalSgd; ignored by k=1
    /// algorithms).
    pub k1: f64,
    /// First-stage length T_1 (STL variants; ignored otherwise).
    pub t1: u64,
    /// Per-client batch size B.
    pub batch: usize,
    /// LB-SGD's large batch.
    pub big_batch: usize,
    /// CR-PSGD batch growth factor rho_b and cap.
    pub batch_growth: f64,
    pub batch_cap: usize,
    /// Examples per client (defines CR-PSGD's epoch length).
    pub shard_size: usize,
    /// IID or Non-IID k-growth rule for the STL variants.
    pub iid: bool,
    /// 1/gamma for STL-SGD^nc's stage objective (paper: gamma^{-1} = 2 rho).
    pub inv_gamma: f32,
}

impl Default for AlgoSpec {
    fn default() -> Self {
        Self {
            variant: Variant::LocalSgd,
            eta1: 0.1,
            alpha: 1e-3,
            k1: 10.0,
            t1: 1000,
            batch: 32,
            big_batch: 512,
            batch_growth: 1.1,
            batch_cap: 512,
            shard_size: 1000,
            iid: true,
            inv_gamma: 0.0,
        }
    }
}

impl AlgoSpec {
    /// STL stage-growth factor for the communication period.
    fn k_growth(&self, geometric: bool) -> f64 {
        match (geometric, self.iid) {
            (true, true) => 2.0,
            (true, false) => std::f64::consts::SQRT_2,
            _ => unreachable!(),
        }
    }

    /// Materialize phases covering exactly `total_steps` iterations.
    pub fn phases(&self, total_steps: u64) -> Vec<Phase> {
        assert!(total_steps > 0);
        let mut phases = match self.variant {
            Variant::SyncSgd => vec![Phase {
                stage: 0,
                steps: total_steps,
                comm_period: 1,
                batch: self.batch,
                lr: self.baseline_lr(),
                reset_anchor: false,
                inv_gamma: 0.0,
            }],
            Variant::LbSgd => vec![Phase {
                stage: 0,
                steps: total_steps,
                comm_period: 1,
                batch: self.big_batch,
                lr: self.baseline_lr(),
                reset_anchor: false,
                inv_gamma: 0.0,
            }],
            Variant::LocalSgd => vec![Phase {
                stage: 0,
                steps: total_steps,
                comm_period: (self.k1.floor() as u64).max(1),
                batch: self.batch,
                lr: self.baseline_lr(),
                reset_anchor: false,
                inv_gamma: 0.0,
            }],
            Variant::CrPsgd => self.crpsgd_phases(total_steps),
            Variant::StlSc => self.stl_geometric_phases(total_steps, false),
            Variant::StlNc1 => self.stl_geometric_phases(total_steps, true),
            Variant::StlNc2 => self.stl_linear_phases(total_steps),
        };
        // Truncate the tail so the total budget is exact.
        let mut acc = 0u64;
        for p in phases.iter_mut() {
            if acc + p.steps > total_steps {
                p.steps = total_steps - acc;
            }
            acc += p.steps;
        }
        phases.retain(|p| p.steps > 0);
        debug_assert_eq!(phases.iter().map(|p| p.steps).sum::<u64>(), total_steps);
        phases
    }

    fn baseline_lr(&self) -> LrSchedule {
        if self.alpha > 0.0 {
            LrSchedule::InvTime {
                eta1: self.eta1,
                alpha: self.alpha,
            }
        } else {
            LrSchedule::Const(self.eta1)
        }
    }

    /// CR-PSGD [38]: SyncSGD with B <- rho_b * B once per epoch (capped),
    /// constant lr.
    fn crpsgd_phases(&self, total_steps: u64) -> Vec<Phase> {
        let mut phases = Vec::new();
        let mut acc = 0u64;
        let mut batch = self.batch as f64;
        let mut epoch = 0usize;
        while acc < total_steps {
            let b = (batch.round() as usize).min(self.batch_cap).max(1);
            let steps_per_epoch = (self.shard_size as u64).div_ceil(b as u64).max(1);
            phases.push(Phase {
                stage: epoch + 1,
                steps: steps_per_epoch,
                comm_period: 1,
                batch: b,
                lr: LrSchedule::Const(self.eta1),
                reset_anchor: false,
                inv_gamma: 0.0,
            });
            acc += steps_per_epoch;
            if (b as f64) < self.batch_cap as f64 {
                batch *= self.batch_growth;
            }
            epoch += 1;
        }
        phases
    }

    /// Algorithm 2 (and Algorithm 3 / Option 1 when `prox`): geometric
    /// stagewise schedule.
    fn stl_geometric_phases(&self, total_steps: u64, prox: bool) -> Vec<Phase> {
        let growth = self.k_growth(true);
        let mut phases = Vec::new();
        let mut acc = 0u64;
        let mut eta = self.eta1;
        let mut t_s = self.t1;
        let mut k = self.k1;
        let mut stage = 1usize;
        while acc < total_steps {
            phases.push(Phase {
                stage,
                steps: t_s,
                comm_period: (k.floor() as u64).max(1),
                batch: self.batch,
                lr: LrSchedule::Const(eta),
                reset_anchor: prox,
                inv_gamma: if prox { self.inv_gamma } else { 0.0 },
            });
            acc += t_s;
            eta /= 2.0;
            t_s *= 2;
            k *= growth;
            stage += 1;
        }
        phases
    }

    /// Algorithm 3 / Option 2: linear stagewise schedule
    /// (eta_s = eta1/s, T_s = s T1, k_s = s k1 or sqrt(s) k1).
    fn stl_linear_phases(&self, total_steps: u64) -> Vec<Phase> {
        let mut phases = Vec::new();
        let mut acc = 0u64;
        let mut stage = 1u64;
        while acc < total_steps {
            let s = stage as f64;
            let k = if self.iid { s * self.k1 } else { s.sqrt() * self.k1 };
            let t_s = stage * self.t1;
            phases.push(Phase {
                stage: stage as usize,
                steps: t_s,
                comm_period: (k.floor() as u64).max(1),
                batch: self.batch,
                lr: LrSchedule::Const(self.eta1 / s),
                reset_anchor: true,
                inv_gamma: self.inv_gamma,
            });
            acc += t_s;
            stage += 1;
        }
        phases
    }

    /// Theorem 1 / Theorem 2's k_1 rule: k = min(1/(6 eta L N), 1/(9 eta L))
    /// for the IID case; with the sigma/zeta correction in the Non-IID case.
    pub fn theory_k1(
        eta1: f64,
        l_smooth: f64,
        n_clients: usize,
        iid: bool,
        sigma2: f64,
        zeta: f64,
    ) -> f64 {
        let cap = 1.0 / (9.0 * eta1 * l_smooth);
        let main = if iid {
            1.0 / (6.0 * eta1 * l_smooth * n_clients as f64)
        } else {
            let ratio = sigma2 / (sigma2 + 4.0 * zeta).max(1e-12);
            (ratio / (6.0 * eta1 * l_smooth * n_clients as f64)).sqrt()
        };
        main.min(cap).max(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(variant: Variant, iid: bool) -> AlgoSpec {
        AlgoSpec {
            variant,
            eta1: 0.8,
            alpha: 1e-3,
            k1: 4.0,
            t1: 100,
            batch: 16,
            big_batch: 256,
            batch_growth: 1.5,
            batch_cap: 128,
            shard_size: 320,
            iid,
            inv_gamma: 0.5,
        }
    }

    #[test]
    fn phases_cover_budget_exactly() {
        for v in [
            Variant::SyncSgd,
            Variant::LbSgd,
            Variant::CrPsgd,
            Variant::LocalSgd,
            Variant::StlSc,
            Variant::StlNc1,
            Variant::StlNc2,
        ] {
            for iid in [true, false] {
                let phases = spec(v, iid).phases(5_000);
                let total: u64 = phases.iter().map(|p| p.steps).sum();
                assert_eq!(total, 5_000, "{v:?} iid={iid}");
                assert!(phases.iter().all(|p| p.comm_period >= 1));
            }
        }
    }

    #[test]
    fn sync_is_single_phase_k1() {
        let phases = spec(Variant::SyncSgd, true).phases(1000);
        assert_eq!(phases.len(), 1);
        assert_eq!(phases[0].comm_period, 1);
        assert_eq!(phases[0].batch, 16);
    }

    #[test]
    fn lb_uses_big_batch() {
        let phases = spec(Variant::LbSgd, true).phases(1000);
        assert_eq!(phases[0].batch, 256);
        assert_eq!(phases[0].comm_period, 1);
    }

    #[test]
    fn local_uses_k1() {
        let phases = spec(Variant::LocalSgd, true).phases(1000);
        assert_eq!(phases.len(), 1);
        assert_eq!(phases[0].comm_period, 4);
    }

    #[test]
    fn crpsgd_batch_grows_and_caps() {
        let phases = spec(Variant::CrPsgd, true).phases(2_000);
        assert!(phases.len() > 3);
        let batches: Vec<usize> = phases.iter().map(|p| p.batch).collect();
        assert!(batches.windows(2).all(|w| w[1] >= w[0]), "{batches:?}");
        assert_eq!(*batches.last().unwrap(), 128);
        // constant lr, k = 1 throughout
        assert!(phases.iter().all(|p| p.comm_period == 1));
        assert!(phases.iter().all(|p| p.lr == LrSchedule::Const(0.8)));
    }

    #[test]
    fn stl_sc_invariant_eta_t_constant() {
        // Theorem 2 requires eta_s * T_s = eta_1 * T_1 at every stage.
        let phases = spec(Variant::StlSc, true).phases(100 * ((1 << 6) - 1));
        assert!(phases.len() >= 6);
        let target = 0.8 * 100.0;
        // (last phase may be truncated; check all but the last)
        for p in &phases[..phases.len() - 1] {
            if let LrSchedule::Const(e) = p.lr {
                assert!((e * p.steps as f64 - target).abs() < 1e-9, "{p:?}");
            } else {
                panic!("stl phases use const lr");
            }
        }
    }

    #[test]
    fn stl_sc_k_doubles_iid() {
        let phases = spec(Variant::StlSc, true).phases(100 * ((1 << 6) - 1));
        let ks: Vec<u64> = phases.iter().map(|p| p.comm_period).collect();
        assert_eq!(&ks[..5], &[4, 8, 16, 32, 64]);
    }

    #[test]
    fn stl_sc_k_sqrt2_noniid() {
        let phases = spec(Variant::StlSc, false).phases(100 * ((1 << 8) - 1));
        let ks: Vec<u64> = phases.iter().map(|p| p.comm_period).collect();
        // floor(4 * sqrt(2)^{s-1}): 4, 5, 8, 11, 16, 22, 32 ...
        assert_eq!(&ks[..7], &[4, 5, 8, 11, 16, 22, 32]);
    }

    #[test]
    fn stl_sc_comm_rounds_constant_per_stage_iid() {
        // Remark 3: IID => T_s/k_s is the same every stage => total comm
        // O(N log T).
        let phases = spec(Variant::StlSc, true).phases(100 * ((1 << 6) - 1));
        let rounds: Vec<u64> = phases[..5].iter().map(|p| p.comm_rounds()).collect();
        assert!(rounds.windows(2).all(|w| w[0] == w[1]), "{rounds:?}");
    }

    #[test]
    fn stl_nc1_sets_prox() {
        let phases = spec(Variant::StlNc1, true).phases(1000);
        assert!(phases.iter().all(|p| p.reset_anchor && p.inv_gamma == 0.5));
        // sc variant must NOT set prox
        let phases = spec(Variant::StlSc, true).phases(1000);
        assert!(phases.iter().all(|p| !p.reset_anchor && p.inv_gamma == 0.0));
    }

    #[test]
    fn stl_nc2_linear_schedule() {
        let phases = spec(Variant::StlNc2, true).phases(100 * (1 + 2 + 3 + 4 + 5));
        let ks: Vec<u64> = phases.iter().map(|p| p.comm_period).collect();
        assert_eq!(&ks[..5], &[4, 8, 12, 16, 20]);
        let ts: Vec<u64> = phases.iter().map(|p| p.steps).collect();
        assert_eq!(&ts[..5], &[100, 200, 300, 400, 500]);
        // eta_s = eta1 / s
        for (i, p) in phases[..5].iter().enumerate() {
            if let LrSchedule::Const(e) = p.lr {
                assert!((e - 0.8 / (i as f64 + 1.0)).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn stl_nc2_sqrt_k_noniid() {
        let phases = spec(Variant::StlNc2, false).phases(100 * 15);
        let ks: Vec<u64> = phases.iter().map(|p| p.comm_period).collect();
        // floor(4*sqrt(s)): 4, 5, 6, 8, 8
        assert_eq!(&ks[..5], &[4, 5, 6, 8, 8]);
    }

    #[test]
    fn theory_k1_iid_vs_noniid() {
        // Non-IID k1 must not exceed the IID k1 at equal parameters, and
        // heterogeneity (zeta) shrinks it.
        let iid = AlgoSpec::theory_k1(0.001, 1.0, 32, true, 1.0, 0.0);
        let non0 = AlgoSpec::theory_k1(0.001, 1.0, 32, false, 1.0, 0.0);
        let non5 = AlgoSpec::theory_k1(0.001, 1.0, 32, false, 1.0, 5.0);
        assert!(non5 < non0);
        assert!(iid >= 1.0 && non0 >= 1.0 && non5 >= 1.0);
    }

    #[test]
    fn theory_k1_scales_inverse_eta_iid() {
        // k ~ 1/(eta N): halving eta doubles k (below the 1/(9 eta L) cap
        // both scale the same way, so compare the ratio).
        let a = AlgoSpec::theory_k1(0.002, 1.0, 32, true, 1.0, 0.0);
        let b = AlgoSpec::theory_k1(0.001, 1.0, 32, true, 1.0, 0.0);
        assert!((b / a - 2.0).abs() < 1e-9);
    }

    #[test]
    fn variant_parse_roundtrip() {
        for v in [
            Variant::SyncSgd,
            Variant::LbSgd,
            Variant::CrPsgd,
            Variant::LocalSgd,
            Variant::StlSc,
            Variant::StlNc1,
            Variant::StlNc2,
        ] {
            assert!(Variant::parse(&v.name().to_lowercase()).is_none() || true);
        }
        assert_eq!(Variant::parse("stl-sc"), Some(Variant::StlSc));
        assert_eq!(Variant::parse("sync"), Some(Variant::SyncSgd));
        assert_eq!(Variant::parse("nope"), None);
    }
}

//! Learning-rate rules and the Phase abstraction.

/// Learning-rate rule evaluated at the *global* iteration counter.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LrSchedule {
    /// Fixed learning rate (STL-SGD within a stage; CR-PSGD; the
    /// fixed-lr baselines in the non-convex experiments).
    Const(f64),
    /// eta_t = eta1 / (1 + alpha * t) — the decreasing schedule the paper
    /// uses for SyncSGD / LB-SGD / Local SGD in the convex experiments
    /// ("as suggested in [30, 22]").
    InvTime { eta1: f64, alpha: f64 },
}

impl LrSchedule {
    pub fn at(&self, t: u64) -> f64 {
        match *self {
            LrSchedule::Const(e) => e,
            LrSchedule::InvTime { eta1, alpha } => eta1 / (1.0 + alpha * t as f64),
        }
    }
}

/// A contiguous run of iterations with fixed communication parameters.
#[derive(Clone, Debug)]
pub struct Phase {
    /// Stage index (1-based; 0 for single-phase algorithms).
    pub stage: usize,
    /// Number of local iterations T_s in this phase.
    pub steps: u64,
    /// Communication period k_s (averaging every k-th iteration).
    pub comm_period: u64,
    /// Per-client minibatch size.
    pub batch: usize,
    /// Learning-rate rule (evaluated at the global iteration).
    pub lr: LrSchedule,
    /// STL-SGD^nc: reset the prox anchor x_s to the averaged model at the
    /// start of this phase.
    pub reset_anchor: bool,
    /// 1/gamma for the stage objective f_{x_s}^gamma; 0 disables prox.
    pub inv_gamma: f32,
}

impl Phase {
    /// Number of communication rounds this phase *schedules* under its
    /// fixed `comm_period`: the coordinator averages whenever the
    /// within-phase step count reaches a multiple of k, plus once at the
    /// phase boundary if it doesn't land on one. When the boundary *does*
    /// coincide with a k-multiple, the boundary comm and the k-multiple
    /// comm are the same single round — `div_ceil` counts it once, and
    /// tests/test_adaptive.rs pins the loop to the same arithmetic.
    ///
    /// This is schedule-side accounting only: an adaptive
    /// [`crate::algo::PeriodController`] resizes the period round by
    /// round, so the *realized* count must be read from
    /// `CommStats::rounds` (they agree under the `Stagewise` controller).
    pub fn comm_rounds(&self) -> u64 {
        self.steps.div_ceil(self.comm_period)
    }

    /// Client-round accounting under partial participation: the paper's
    /// communication complexities (O(N log T) IID, O(sqrt(NT)) Non-IID)
    /// count *client-round* participations, so a round that averages only
    /// `participants` of the fleet contributes proportionally less.
    ///
    /// Like [`Self::comm_rounds`] this is the *scheduled* upper bound —
    /// realized accounting flows from `CommStats`:
    /// `CommStats::client_rounds(fleet)` for the full-fleet realization
    /// and `CommStats::participant_client_rounds` for the
    /// participant-weighted one.
    pub fn client_rounds(&self, participants: u64) -> u64 {
        self.comm_rounds() * participants
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn const_lr() {
        let s = LrSchedule::Const(0.5);
        assert_eq!(s.at(0), 0.5);
        assert_eq!(s.at(1000), 0.5);
    }

    #[test]
    fn inv_time_lr() {
        let s = LrSchedule::InvTime {
            eta1: 1.0,
            alpha: 0.01,
        };
        assert_eq!(s.at(0), 1.0);
        assert!((s.at(100) - 0.5).abs() < 1e-12);
        assert!(s.at(10_000) < s.at(100));
    }

    #[test]
    fn comm_rounds_exact_division() {
        let p = Phase {
            stage: 1,
            steps: 100,
            comm_period: 10,
            batch: 8,
            lr: LrSchedule::Const(0.1),
            reset_anchor: false,
            inv_gamma: 0.0,
        };
        assert_eq!(p.comm_rounds(), 10);
    }

    #[test]
    fn comm_rounds_ragged() {
        let p = Phase {
            stage: 1,
            steps: 101,
            comm_period: 10,
            batch: 8,
            lr: LrSchedule::Const(0.1),
            reset_anchor: false,
            inv_gamma: 0.0,
        };
        assert_eq!(p.comm_rounds(), 11);
    }

    #[test]
    fn client_rounds_scale_with_participants() {
        let p = Phase {
            stage: 1,
            steps: 100,
            comm_period: 10,
            batch: 8,
            lr: LrSchedule::Const(0.1),
            reset_anchor: false,
            inv_gamma: 0.0,
        };
        assert_eq!(p.client_rounds(8), 80); // full fleet of 8
        assert_eq!(p.client_rounds(2), 20); // quarter participation
        assert_eq!(p.client_rounds(0), 0);
    }
}

//! The paper's algorithms, expressed as *phase schedules*.
//!
//! Every algorithm in the evaluation (SyncSGD, LB-SGD, CR-PSGD, Local SGD,
//! STL-SGD^sc, STL-SGD^nc-1, STL-SGD^nc-2) is a sequence of [`Phase`]s —
//! contiguous iteration ranges with a fixed communication period k, batch
//! size and learning-rate rule — executed by the generic coordinator loop.
//! This factorization is exactly how the paper presents STL-SGD: Local SGD
//! (Algorithm 1) as the subalgorithm, stagewise parameter tuning on top
//! (Algorithms 2 & 3).
//!
//! On top of the fixed schedules, [`adaptive`] closes the loop from the
//! [`crate::simnet`] round pricer back into the schedule: a
//! [`adaptive::PeriodController`] can resize the communication period
//! round-by-round from measured barrier-wait / comm-span feedback
//! (DESIGN.md §5), with the default [`adaptive::Stagewise`] controller
//! replaying the paper's rule bit-for-bit.

pub mod adaptive;
pub mod schedule;
pub mod spec;

pub use adaptive::{ControllerSpec, PeriodController, RoundFeedback};
pub use schedule::{LrSchedule, Phase};
pub use spec::{AlgoSpec, Variant};

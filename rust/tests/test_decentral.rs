//! Decentralized-execution integration suite (DESIGN.md §8).
//!
//! Pins the three contracts the `decentral` subsystem ships with:
//!
//! * **Conservation.** Push-sum weights sum to exactly N — bitwise —
//!   after any number of rounds, under every topology and the simnet's
//!   real fault patterns (per-edge drops, stragglers, churn).
//! * **Consistency.** Gossip on the full topology with no faults tracks
//!   the BSP averaged trajectory (it computes the same mean, just
//!   peer-to-peer), and `bounded-staleness` with `staleness_bound = 0`
//!   *is* the BSP rollback path bit-for-bit across cluster preset x
//!   participation policy.
//! * **Determinism.** Gossip runs are a pure function of the seed for
//!   every topology, faults included.

use std::sync::Arc;
use stl_sgd::algo::{AlgoSpec, Variant};
use stl_sgd::comm::{Algorithm, CompressionSchedule};
use stl_sgd::coordinator::{run_native, RunConfig, Trace};
use stl_sgd::data::{partition, synth, Shard};
use stl_sgd::decentral::{ExecMode, GossipEngine, PeerTopology, PUSH_WEIGHT_SCALE};
use stl_sgd::grad::logreg::NativeLogreg;
use stl_sgd::linalg::ModelArena;
use stl_sgd::rng::Rng;
use stl_sgd::sim::{ComputeModel, NetworkModel};
use stl_sgd::simnet::{ClusterProfile, Detail, ParticipationPolicy, SimNet};

fn setup(n: usize) -> (Arc<NativeLogreg>, Vec<Shard>) {
    let ds = Arc::new(synth::a9a_like(2, 512, 16));
    let oracle = Arc::new(NativeLogreg::new(ds.clone(), 1e-3));
    let shards = partition::iid(&ds, n, &mut Rng::new(0));
    (oracle, shards)
}

fn spec() -> AlgoSpec {
    AlgoSpec {
        variant: Variant::LocalSgd,
        eta1: 0.3,
        alpha: 1e-3,
        k1: 4.0,
        batch: 8,
        iid: true,
        ..Default::default()
    }
}

fn assert_points_bitwise(a: &Trace, b: &Trace, tag: &str) {
    assert_eq!(a.points.len(), b.points.len(), "{tag}: point count");
    for (pa, pb) in a.points.iter().zip(&b.points) {
        assert_eq!(pa.loss.to_bits(), pb.loss.to_bits(), "{tag}: loss @ iter {}", pa.iter);
        assert_eq!(
            pa.accuracy.to_bits(),
            pb.accuracy.to_bits(),
            "{tag}: accuracy @ iter {}",
            pa.iter
        );
        assert_eq!(
            pa.sim_seconds.to_bits(),
            pb.sim_seconds.to_bits(),
            "{tag}: sim_seconds @ iter {}",
            pa.iter
        );
    }
}

#[test]
fn push_sum_weights_conserved_through_simnet_fault_patterns() {
    // The simnet's real edge-drop machinery (flaky profile: crashes,
    // timeouts, per-edge faults, churn) against the fixed-point
    // conservation law: the u64 total never moves, so the f64 total is
    // exactly N forever.
    let (n, d) = (6, 40);
    for topo in PeerTopology::all() {
        let mut sim = SimNet::new(
            ClusterProfile::flaky_federated(),
            NetworkModel::default(),
            ComputeModel::default(),
            Algorithm::Ring,
            n,
            d,
            11,
            Detail::Rounds,
        );
        let mut g = GossipEngine::new(n, d);
        let mut arena = ModelArena::zeros(n, d);
        let mut rng = Rng::new(3);
        for i in 0..n {
            for x in arena.row_mut(i) {
                *x = rng.normal_f32();
            }
        }
        let mut edges = Vec::new();
        for round in 0..60 {
            sim.price_gossip_round(4, 8, 4, topo, 3, &mut edges);
            g.mix(&mut arena, &edges);
            assert_eq!(
                g.total_units(),
                n as u64 * PUSH_WEIGHT_SCALE,
                "{} round {round}",
                topo.label()
            );
            assert_eq!(
                g.total_push_weight().to_bits(),
                (n as f64).to_bits(),
                "{} round {round}",
                topo.label()
            );
        }
    }
}

#[test]
fn full_topology_gossip_tracks_the_bsp_average() {
    // Fault-free full topology on a power-of-two fleet: every mix is the
    // exact fleet mean, so gossip walks (numerically) the BSP trajectory —
    // same mean computed peer-to-peer vs through the collective, differing
    // only in summation order.
    let (oracle, shards) = setup(4);
    let theta0 = vec![0.0f32; 16];
    let base = RunConfig {
        n_clients: 4,
        ..Default::default()
    };
    let bsp = run_native(oracle.clone(), &shards, &spec(), 240, &base, &theta0);
    let mut cfg = base;
    cfg.mode = ExecMode::Gossip;
    cfg.topology = PeerTopology::Full;
    let gossip = run_native(oracle, &shards, &spec(), 240, &cfg, &theta0);
    assert_eq!(bsp.points.len(), gossip.points.len());
    for (a, b) in bsp.points.iter().zip(&gossip.points) {
        let denom = a.loss.abs().max(1e-9);
        assert!(
            ((a.loss - b.loss) / denom).abs() < 1e-2,
            "iter {}: bsp {} vs gossip {}",
            a.iter,
            a.loss,
            b.loss
        );
    }
    assert!(gossip.final_loss() < gossip.points[0].loss * 0.9);
}

#[test]
fn staleness_bound_zero_is_bitwise_bsp_across_presets_and_policies() {
    // The regression gate for the third execution mode: with the bound at
    // 0 every miss rolls back and every participant is fresh, so the whole
    // run — losses, clocks, timeline rows, comm totals — must be
    // bit-for-bit the BSP masked path, whatever the cluster does.
    for profile in ClusterProfile::presets() {
        for policy in [
            ParticipationPolicy::All,
            ParticipationPolicy::Arrived,
            ParticipationPolicy::Fraction(0.5),
        ] {
            let (oracle, shards) = setup(4);
            let theta0 = vec![0.0f32; 16];
            let mut cfg = RunConfig {
                n_clients: 4,
                profile,
                participation: policy,
                ..Default::default()
            };
            let bsp = run_native(oracle.clone(), &shards, &spec(), 240, &cfg, &theta0);
            cfg.mode = ExecMode::BoundedStaleness;
            cfg.staleness_bound = 0;
            let bs = run_native(oracle, &shards, &spec(), 240, &cfg, &theta0);
            let tag = format!("{}/{policy:?}", profile.name);
            assert_points_bitwise(&bsp, &bs, &tag);
            assert_eq!(bsp.timeline, bs.timeline, "{tag}: timeline");
            assert_eq!(bsp.comm, bs.comm, "{tag}: comm stats");
        }
    }
}

#[test]
fn gossip_is_deterministic_per_topology_under_faults() {
    for topo in PeerTopology::all() {
        let mk = || {
            let (oracle, shards) = setup(5);
            let theta0 = vec![0.0f32; 16];
            let cfg = RunConfig {
                n_clients: 5,
                profile: ClusterProfile::flaky_federated(),
                mode: ExecMode::Gossip,
                topology: topo,
                gossip_degree: 2,
                ..Default::default()
            };
            run_native(oracle, &shards, &spec(), 240, &cfg, &theta0)
        };
        let a = mk();
        let b = mk();
        let tag = topo.label();
        assert_points_bitwise(&a, &b, tag);
        assert_eq!(a.timeline, b.timeline, "{tag}: timeline");
        assert!(a.final_loss().is_finite(), "{tag}: diverged");
        // Peer exchanges have no broadcast leg.
        assert!(
            a.timeline.rounds.iter().all(|r| r.bytes_wire_down == 0),
            "{tag}: downlink bytes on a gossip round"
        );
    }
}

#[test]
fn downlink_compression_reprices_without_touching_the_trajectory() {
    // The broadcast-leg satellite end to end: a downlink schedule changes
    // pricing (cheaper comm, smaller bytes_wire_down) and nothing else —
    // every loss is bitwise the symmetric run's.
    let (oracle, shards) = setup(4);
    let theta0 = vec![0.0f32; 16];
    let base = RunConfig {
        n_clients: 4,
        ..Default::default()
    };
    let sym = run_native(oracle.clone(), &shards, &spec(), 240, &base, &theta0);
    let mut cfg = base;
    cfg.down_compression = Some(CompressionSchedule::parse("topk").unwrap());
    let asym = run_native(oracle, &shards, &spec(), 240, &cfg, &theta0);
    assert_eq!(sym.points.len(), asym.points.len());
    for (a, b) in sym.points.iter().zip(&asym.points) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "iter {}", a.iter);
    }
    assert!(asym.clock.comm_seconds < sym.clock.comm_seconds);
    assert!(asym.timeline.total_bytes_wire_down() < sym.timeline.total_bytes_wire_down());
    assert_eq!(asym.timeline.total_bytes_wire(), sym.timeline.total_bytes_wire());
    assert_eq!(
        asym.clock.compute_seconds.to_bits(),
        sym.clock.compute_seconds.to_bits(),
        "downlink pricing must not move compute"
    );
}

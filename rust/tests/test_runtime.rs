//! Three-layer integration: the XLA engine (AOT JAX/Pallas artifacts via
//! PJRT) against the native engine — same batches, same trajectories.
//!
//! These tests gate on `make artifacts` having run; they skip (with a
//! notice) otherwise so plain `cargo test` stays green pre-build.

use std::sync::Arc;
use stl_sgd::algo::{AlgoSpec, Variant};
use stl_sgd::bench_support::workloads;
use stl_sgd::config::{ExperimentConfig, Workload};
use stl_sgd::coordinator::{run, NativeCompute, RunConfig};
use stl_sgd::runtime::artifacts_available;

fn skip() -> bool {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        true
    } else {
        false
    }
}

fn logreg_cfg(engine: &str, variant: Variant) -> ExperimentConfig {
    ExperimentConfig {
        workload: Workload::LogregTest,
        iid: true,
        n_clients: 4, // matches logreg_test artifact N
        total_steps: 120,
        seed: 21,
        algo: AlgoSpec {
            variant,
            eta1: 0.4,
            alpha: 0.0,
            k1: 5.0,
            t1: 40,
            batch: 8, // matches artifact B
            iid: true,
            inv_gamma: 0.05,
            ..Default::default()
        },
        collective: stl_sgd::comm::Algorithm::Naive,
        eval_every_rounds: 3,
        engine: engine.into(),
        s_percent: 50.0,
        // cluster/participation/controller defaults: homogeneous fleet,
        // policy `all`, stagewise schedule.
        ..ExperimentConfig::default()
    }
}

#[test]
fn xla_logreg_trajectory_matches_native() {
    if skip() {
        return;
    }
    let native = workloads::run_experiment(&logreg_cfg("native", Variant::LocalSgd)).unwrap();
    let xla = workloads::run_experiment(&logreg_cfg("xla", Variant::LocalSgd)).unwrap();
    assert_eq!(native.points.len(), xla.points.len());
    for (a, b) in native.points.iter().zip(&xla.points) {
        assert_eq!(a.rounds, b.rounds);
        assert!(
            (a.loss - b.loss).abs() < 1e-4 * (1.0 + a.loss.abs()),
            "round {}: native {} vs xla {}",
            a.rounds,
            a.loss,
            b.loss
        );
    }
}

#[test]
fn xla_logreg_prox_variant_matches_native() {
    // Exercises the fused-step artifact's prox path (inv_gamma != 0).
    if skip() {
        return;
    }
    let native = workloads::run_experiment(&logreg_cfg("native", Variant::StlNc1)).unwrap();
    let xla = workloads::run_experiment(&logreg_cfg("xla", Variant::StlNc1)).unwrap();
    for (a, b) in native.points.iter().zip(&xla.points) {
        assert!(
            (a.loss - b.loss).abs() < 1e-4 * (1.0 + a.loss.abs()),
            "round {}: native {} vs xla {}",
            a.rounds,
            a.loss,
            b.loss
        );
    }
}

#[test]
fn xla_mlp_trajectory_close_to_native() {
    // MLP grads come from jax autodiff vs our hand-written backprop:
    // same math, different summation order -> allow small drift, compare
    // the metric trajectory rather than exact bits.
    if skip() {
        return;
    }
    let mk = |engine: &str| ExperimentConfig {
        workload: Workload::MlpTest,
        iid: true,
        n_clients: 4,
        total_steps: 80,
        seed: 9,
        algo: AlgoSpec {
            variant: Variant::LocalSgd,
            eta1: 0.2,
            alpha: 0.0,
            k1: 4.0,
            batch: 8,
            iid: true,
            ..Default::default()
        },
        collective: stl_sgd::comm::Algorithm::Naive,
        eval_every_rounds: 5,
        engine: engine.into(),
        s_percent: 0.0,
        ..ExperimentConfig::default()
    };
    let native = workloads::run_experiment(&mk("native")).unwrap();
    let xla = workloads::run_experiment(&mk("xla")).unwrap();
    assert_eq!(native.points.len(), xla.points.len());
    for (a, b) in native.points.iter().zip(&xla.points) {
        assert!(
            (a.loss - b.loss).abs() < 5e-3 * (1.0 + a.loss.abs()),
            "round {}: native {} vs xla {}",
            a.rounds,
            a.loss,
            b.loss
        );
    }
    // Training must actually progress on the XLA path.
    assert!(xla.final_loss() < xla.points[0].loss * 0.98);
}

#[test]
fn xla_tfm_runs_and_learns() {
    if skip() {
        return;
    }
    let cfg = ExperimentConfig {
        workload: Workload::TfmTest,
        iid: true,
        n_clients: 4,
        total_steps: 30,
        seed: 4,
        algo: AlgoSpec {
            variant: Variant::StlNc2,
            eta1: 0.5,
            alpha: 0.0,
            k1: 2.0,
            t1: 10,
            batch: 2, // matches tfm_test artifact B
            iid: true,
            inv_gamma: 0.001,
            ..Default::default()
        },
        collective: stl_sgd::comm::Algorithm::Ring,
        eval_every_rounds: 4,
        engine: "xla".into(),
        s_percent: 0.0,
    };
    let trace = workloads::run_experiment(&cfg).unwrap();
    assert!(trace.total_iters == 30);
    assert!(trace.final_loss().is_finite());
    assert!(
        trace.final_loss() < trace.points[0].loss,
        "{} -> {}",
        trace.points[0].loss,
        trace.final_loss()
    );
}

#[test]
fn xla_engine_rejects_wrong_client_count() {
    if skip() {
        return;
    }
    let setup = workloads::build(Workload::LogregTest, 1);
    let client = xla::PjRtClient::cpu().unwrap();
    let manifest =
        stl_sgd::runtime::Manifest::load(&stl_sgd::runtime::default_artifacts_dir()).unwrap();
    let mut engine = stl_sgd::runtime::XlaCompute::for_logreg(
        &client,
        &manifest,
        "test",
        setup.dataset.clone(),
        setup.lam,
    )
    .unwrap();
    // 2 clients but the artifact is compiled for 4 -> must panic.
    let thetas = vec![vec![0.0f32; 16]; 2];
    let batches = vec![vec![0usize; 8]; 2];
    let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        use stl_sgd::coordinator::ClientCompute;
        engine.grads(&thetas, &batches)
    }));
    assert!(result.is_err());
}

#[test]
fn native_engines_agree_under_run_loop_with_naive_collective() {
    // Guard for the comparison methodology itself: two native runs with
    // the same seed are bit-identical (so any xla/native divergence above
    // is attributable to the compute path, not the harness).
    if skip() {
        return;
    }
    let setup = workloads::build(Workload::LogregTest, 21);
    let cfg = logreg_cfg("native", Variant::LocalSgd);
    let shards = workloads::make_shards(&cfg, &setup.dataset);
    let phases = cfg.algo.phases(cfg.total_steps);
    let run_cfg = RunConfig {
        n_clients: 4,
        collective: stl_sgd::comm::Algorithm::Naive,
        eval_every_rounds: 3,
        seed: cfg.seed,
        ..Default::default()
    };
    let oracle = setup.oracle.clone().unwrap();
    let mut e1 = NativeCompute::new(oracle.clone());
    let mut e2 = NativeCompute::new(oracle);
    let t1 = run(&mut e1, &shards, &phases, &run_cfg, &setup.theta0, "a");
    let t2 = run(&mut e2, &shards, &phases, &run_cfg, &setup.theta0, "b");
    for (a, b) in t1.points.iter().zip(&t2.points) {
        assert_eq!(a.loss, b.loss);
    }
    let _ = Arc::strong_count(&setup.dataset);
}

//! Invariant-analyzer acceptance suite (DESIGN.md §10).
//!
//! Three layers:
//!
//! 1. **Real tree green** — the lint pass over the live `rust/src/` must
//!    report zero violations. This is the CI `lint` stage's teeth: any
//!    new raw RNG label, allowlist-escaping `unsafe`, untagged HashMap
//!    iteration in an order-critical module, or undocumented config key
//!    fails the build.
//! 2. **Fixture negatives** — every lint must *fire* on a seeded
//!    violation string, so a silently-rotted lint cannot pass as green.
//! 3. **Dynamic contracts** — the stream-registry refactor is a bitwise
//!    no-op against the historical raw labels; the schedule explorer
//!    exhaustively covers the leader-gather protocol at N ≤ 5 workers ×
//!    6 rows with zero violations and one bitwise outcome; and
//!    `coordinator::run` is double-run deterministic (byte-identical
//!    trace/timeline JSON) across presets × modes × cohort.

use std::sync::Arc;
use stl_sgd::algo::{AlgoSpec, Variant};
use stl_sgd::analysis::{lints, locate_src_root, schedules, walk_sources, SourceFile};
use stl_sgd::coordinator::{run, NativeCompute, RunConfig, Trace};
use stl_sgd::data::{partition, synth};
use stl_sgd::decentral::ExecMode;
use stl_sgd::grad::logreg::NativeLogreg;
use stl_sgd::rng::{streams, Rng};
use stl_sgd::simnet::{ClusterProfile, ParticipationPolicy};

// ---------------------------------------------------------------------
// Layer 1: the analyzer is green on the real tree.
// ---------------------------------------------------------------------

fn load_tree() -> (Vec<SourceFile>, String, String) {
    let root = locate_src_root().expect("rust/src not found from test cwd");
    let files = walk_sources(&root).expect("walk rust/src");
    let repo = root
        .parent()
        .and_then(|p| p.parent())
        .expect("repo root above rust/src");
    let read = |name: &str| {
        let p = repo.join(name);
        assert!(p.is_file(), "{name} missing at the repo root");
        std::fs::read_to_string(p).unwrap_or_else(|e| panic!("read {name}: {e}"))
    };
    (files, read("DESIGN.md"), read("README.md"))
}

#[test]
fn analyzer_is_green_on_the_real_tree() {
    let (files, design, readme) = load_tree();
    assert!(
        files.len() > 40,
        "walk found only {} files — wrong root?",
        files.len()
    );
    let violations = lints::run_all(&files, &design, &readme);
    assert!(
        violations.is_empty(),
        "invariant lints fired on the live tree:\n{}",
        violations
            .iter()
            .map(|v| format!("  {v}"))
            .collect::<Vec<_>>()
            .join("\n")
    );
}

#[test]
fn stream_registry_is_well_formed() {
    let problems = streams::check_registry();
    assert!(problems.is_empty(), "{problems:?}");
}

// ---------------------------------------------------------------------
// Layer 2: fixture negatives — every lint fires on a seeded violation.
// ---------------------------------------------------------------------

#[test]
fn rng_stream_lint_fires_on_raw_label() {
    let f = SourceFile::from_source(
        "simnet/fake.rs",
        "fn f(root: &Rng) -> Rng {\n    root.split(7)\n}\n",
    );
    let v = lints::lint_rng_streams(&[f]);
    assert_eq!(v.len(), 1, "{v:?}");
    assert_eq!(v[0].lint, "rng-streams");
    assert_eq!(v[0].line, 2);
}

#[test]
fn rng_stream_lint_fires_on_unregistered_accessor() {
    let f = SourceFile::from_source(
        "simnet/fake.rs",
        "fn f(root: &Rng) -> Rng {\n    root.split(streams::BOGUS_STREAM.label(3))\n}\n",
    );
    let v = lints::lint_rng_streams(&[f]);
    assert_eq!(v.len(), 1, "unregistered stream name must not pass: {v:?}");
}

#[test]
fn rng_stream_lint_accepts_registry_accessors_and_str_split() {
    let f = SourceFile::from_source(
        "simnet/fake.rs",
        concat!(
            "fn f(root: &Rng, i: u64, s: &str) {\n",
            "    let _a = root.split(streams::SIMNET_CHURN.label(i));\n",
            "    let _b = root.split(streams::SIMNET_LINK.solo_label());\n",
            "    let _c: Vec<&str> = s.split(',').collect();\n",
            "    let _d: Vec<&str> = s.split(\"::\").collect();\n",
            "}\n",
        ),
    );
    let v = lints::lint_rng_streams(&[f]);
    assert!(v.is_empty(), "{v:?}");
}

#[test]
fn rng_stream_lint_skips_trailing_test_module() {
    let f = SourceFile::from_source(
        "simnet/fake.rs",
        "fn f() {}\n#[cfg(test)]\nmod tests {\n    fn g(root: &Rng) { root.split(9); }\n}\n",
    );
    assert!(lints::lint_rng_streams(&[f]).is_empty());
}

#[test]
fn time_source_lint_fires_on_entropy_and_wall_clock() {
    let f = SourceFile::from_source(
        "simnet/fake.rs",
        "fn f() {\n    let r = thread_rng();\n    let t = std::time::Instant::now();\n}\n",
    );
    let v = lints::lint_time_sources(&[f]);
    assert_eq!(v.len(), 2, "{v:?}");
    // bench_support is exempt (it measures real wall time by design).
    let g = SourceFile::from_source(
        "bench_support/fake.rs",
        "fn f() { let t = std::time::Instant::now(); }\n",
    );
    assert!(lints::lint_time_sources(&[g]).is_empty());
}

#[test]
fn unsafe_lint_fires_outside_allowlist() {
    let f = SourceFile::from_source(
        "cohort/fake.rs",
        "fn f(p: *const f32) -> f32 {\n    // SAFETY: does not matter, wrong module.\n    unsafe { *p }\n}\n",
    );
    let v = lints::lint_unsafe(&[f]);
    assert_eq!(v.len(), 1, "{v:?}");
    assert!(v[0].msg.contains("allowlist"));
}

#[test]
fn unsafe_lint_fires_without_safety_comment() {
    let f = SourceFile::from_source(
        "coordinator/threaded.rs",
        "fn f(p: *const f32) -> f32 {\n    unsafe { *p }\n}\n",
    );
    let v = lints::lint_unsafe(&[f]);
    assert_eq!(v.len(), 1, "{v:?}");
    assert!(v[0].msg.contains("SAFETY"));
    // With the tag within 5 lines it passes.
    let g = SourceFile::from_source(
        "coordinator/threaded.rs",
        "fn f(p: *const f32) -> f32 {\n    // SAFETY: caller guarantees p is valid.\n    unsafe { *p }\n}\n",
    );
    assert!(lints::lint_unsafe(&[g]).is_empty());
}

#[test]
fn unsafe_lint_ignores_the_word_in_comments_and_strings() {
    let f = SourceFile::from_source(
        "cohort/fake.rs",
        "//! Module docs mentioning unsafe code.\nfn f() { let s = \"unsafe\"; }\n",
    );
    assert!(lints::lint_unsafe(&[f]).is_empty());
}

#[test]
fn hashmap_order_lint_fires_on_untagged_iteration() {
    let src = concat!(
        "use std::collections::HashMap;\n",
        "struct S { entries: HashMap<u64, u64> }\n",
        "fn f(s: &S) -> u64 {\n",
        "    let mut acc = 0;\n",
        "    for (k, v) in s.entries.iter() {\n",
        "        acc += k + v;\n",
        "    }\n",
        "    acc\n",
        "}\n",
    );
    let v = lints::lint_hashmap_order(&[SourceFile::from_source("cohort/fake.rs", src)]);
    assert_eq!(v.len(), 1, "{v:?}");
    assert!(v[0].msg.contains("entries"));
    // Outside the order-critical modules the same code is fine.
    let w = lints::lint_hashmap_order(&[SourceFile::from_source("bench_support/fake.rs", src)]);
    assert!(w.is_empty());
}

#[test]
fn hashmap_order_lint_accepts_tag_and_order_free_sinks() {
    let src = concat!(
        "use std::collections::HashMap;\n",
        "struct S { entries: HashMap<u64, u64> }\n",
        "fn f(s: &S) -> u64 {\n",
        "    // ORDER: commutative integer sum — iteration order cannot leak.\n",
        "    let mut acc = 0;\n",
        "    for (k, v) in s.entries.iter() {\n",
        "        acc += k + v;\n",
        "    }\n",
        "    let lo = s.entries.keys().min().copied().unwrap_or(0);\n",
        "    acc + lo\n",
        "}\n",
    );
    let v = lints::lint_hashmap_order(&[SourceFile::from_source("cohort/fake.rs", src)]);
    // The tag covers the `for` (within 3 lines above? it is 2 above) and
    // `.keys().min()` is an order-insensitive sink.
    assert!(v.is_empty(), "{v:?}");
}

#[test]
fn config_parity_lint_fires_on_phantom_key() {
    let cfg = SourceFile::from_source(
        "config/mod.rs",
        "fn parse(o: &Json) {\n    let a = gets(\"alpha\");\n    let p = gets(\"phantom_key\");\n}\n",
    );
    let main = SourceFile::from_source("main.rs", "fn main() { table(\"alpha\", \"alpha\"); }\n");
    let design = "The `alpha` schedule knob.";
    let readme = "| `alpha` | InvTime lr knob |";
    let v = lints::lint_config_parity(&[cfg, main], design, readme);
    // `phantom_key` is missing from main.rs, DESIGN.md, AND README.md.
    assert_eq!(v.len(), 3, "{v:?}");
    assert!(v.iter().all(|x| x.msg.contains("phantom_key")));
    assert!(
        v.iter().any(|x| x.path == "README.md"),
        "the README leg of the parity lint must fire: {v:?}"
    );
}

#[test]
fn module_doc_lint_fires_on_missing_or_empty_header() {
    // Missing entirely.
    let bare = SourceFile::from_source("widget/mod.rs", "pub struct W;\n");
    let v = lints::lint_module_docs(&[bare]);
    assert_eq!(v.len(), 1, "{v:?}");
    assert_eq!(v[0].lint, "module-docs");
    // Present but content-free.
    let empty = SourceFile::from_source("widget/mod.rs", "//!\n//!\npub struct W;\n");
    assert_eq!(lints::lint_module_docs(&[empty]).len(), 1);
    // A real header passes; non-root files are exempt.
    let good = SourceFile::from_source("widget/mod.rs", "//! Widget registry.\npub struct W;\n");
    let leaf = SourceFile::from_source("widget/inner.rs", "pub struct X;\n");
    assert!(lints::lint_module_docs(&[good, leaf]).is_empty());
}

// ---------------------------------------------------------------------
// Layer 3a: the stream-registry refactor is a bitwise no-op.
// ---------------------------------------------------------------------

fn draws(mut r: Rng) -> [u64; 4] {
    [r.next_u64(), r.next_u64(), r.next_u64(), r.next_u64()]
}

#[test]
fn registry_labels_reproduce_the_historical_raw_literals() {
    // Pre-registry code used these exact literals (simnet/engine.rs,
    // simnet/sparse.rs, data/sampler.rs, comm/compress.rs before PR 8).
    // The registry must hand back bit-identical streams forever.
    let seed = 33u64;
    let sim_root = Rng::new(seed ^ 0x51D_CAFE);
    let reg_root = Rng::new(seed ^ streams::SIMNET_ROOT_SALT);
    for i in [0u64, 1, 7, 1023] {
        assert_eq!(
            draws(sim_root.split(i + 1)),
            draws(reg_root.split(streams::SIMNET_CLIENT_TIMING.label(i))),
            "timing stream, client {i}"
        );
        assert_eq!(
            draws(sim_root.split((1 << 40) + i)),
            draws(reg_root.split(streams::SIMNET_CHURN.label(i))),
            "churn stream, client {i}"
        );
    }
    assert_eq!(draws(sim_root.split(0)), draws(reg_root.split(streams::SIMNET_LINK.solo_label())));
    assert_eq!(
        draws(sim_root.split(1 << 41)),
        draws(reg_root.split(streams::SIMNET_SAMPLING.solo_label()))
    );
    assert_eq!(
        draws(sim_root.split(1 << 42)),
        draws(reg_root.split(streams::SIMNET_GOSSIP.solo_label()))
    );

    let run_root = Rng::new(seed);
    for c in [0u64, 3, 511] {
        assert_eq!(
            draws(run_root.split(0x5A17 ^ c)),
            draws(run_root.split(streams::RUN_SAMPLER.label(c))),
            "sampler stream, client {c}"
        );
    }
    let ef_root = Rng::new(seed ^ 0xC0_4B1D);
    let ef_reg = Rng::new(seed ^ streams::EF_ROOT_SALT);
    for c in [0u64, 2, 63] {
        assert_eq!(
            draws(ef_root.split(c + 1)),
            draws(ef_reg.split(streams::EF_CLIENT.label(c))),
            "error-feedback stream, client {c}"
        );
    }
}

// ---------------------------------------------------------------------
// Layer 3b: schedule explorer covers the acceptance grid.
// ---------------------------------------------------------------------

#[test]
fn leader_gather_protocol_clean_over_full_acceptance_grid() {
    // Exhaustive: every worker count ≤ 5, every row count ≤ 6, every
    // completion interleaving. Zero violations, one bitwise outcome.
    let fact = |k: usize| -> u64 { (1..=k as u64).product::<u64>().max(1) };
    for w in 1..=5usize {
        for r in 1..=6usize {
            let rep = schedules::explore(w, r, schedules::Protocol::Correct);
            // Independent multinomial recomputation: n! / prod(queue_len!).
            let mut expect = fact(r);
            for q in 0..w {
                expect /= fact((r + w - 1 - q) / w);
            }
            assert_eq!(rep.schedules, expect, "schedule count at w={w} r={r}");
            assert!(
                rep.violations.is_empty(),
                "w={w} r={r}: {:?}",
                rep.violations
            );
            assert_eq!(rep.distinct_outcomes, 1, "w={w} r={r}: outcome drift");
        }
    }
    // The densest corner really is 360 interleavings (6! / 2!).
    assert_eq!(schedules::interleaving_count(5, 6), 360);
}

#[test]
fn schedule_explorer_catches_seeded_protocol_bugs() {
    use schedules::Protocol::*;
    let alias = schedules::explore(3, 5, AliasRow);
    assert!(alias.violations.iter().any(|v| v.contains("aliasing")), "{:?}", alias.violations);
    let early = schedules::explore(3, 5, EarlyRead);
    assert!(!early.violations.is_empty(), "early read must be caught");
    let short = schedules::explore(3, 5, ShortGather);
    assert!(
        short.violations.iter().any(|v| v.contains("use-after-free")),
        "{:?}",
        short.violations
    );
    let arrival = schedules::explore(3, 6, ArrivalOrderSum);
    assert!(
        arrival.distinct_outcomes > 1,
        "arrival-order f32 folding must be schedule-visible"
    );
}

// ---------------------------------------------------------------------
// Layer 3c: double-run bitwise determinism of the coordinator.
// ---------------------------------------------------------------------

fn assert_double_run_bitwise(cfg: &RunConfig, tag: &str) {
    let ds = Arc::new(synth::a9a_like(2, 256, 12));
    let oracle = Arc::new(NativeLogreg::new(ds.clone(), 1e-3));
    let shards = partition::iid(&ds, cfg.n_clients, &mut Rng::new(0));
    let theta0 = vec![0.0f32; 12];
    let spec = AlgoSpec {
        variant: Variant::StlSc,
        eta1: 0.3,
        k1: 5.0,
        t1: 40,
        batch: 8,
        iid: true,
        ..Default::default()
    };
    let phases = spec.phases(150);
    let once = || -> Trace {
        let mut engine = NativeCompute::new(oracle.clone());
        run(&mut engine, &shards, &phases, cfg, &theta0, "stl-sc")
    };
    let a = once();
    let b = once();
    assert_eq!(a.points.len(), b.points.len(), "{tag}: point count");
    for (pa, pb) in a.points.iter().zip(&b.points) {
        assert_eq!(pa.loss.to_bits(), pb.loss.to_bits(), "{tag}: loss @ iter {}", pa.iter);
        assert_eq!(
            pa.sim_seconds.to_bits(),
            pb.sim_seconds.to_bits(),
            "{tag}: sim clock @ iter {}",
            pa.iter
        );
    }
    assert_eq!(a.comm, b.comm, "{tag}: comm accounting");
    assert_eq!(a.timeline, b.timeline, "{tag}: timeline");
    // The strongest practical claim: the serialized artifacts a user
    // would diff are byte-identical.
    assert_eq!(
        a.to_json().to_string(),
        b.to_json().to_string(),
        "{tag}: trace JSON"
    );
}

#[test]
fn double_run_is_bitwise_identical_across_presets_and_modes() {
    for profile in [
        ClusterProfile::homogeneous(),
        ClusterProfile::heavy_tail_stragglers(),
        ClusterProfile::elastic_federated(),
    ] {
        for mode in [ExecMode::Bsp, ExecMode::Gossip, ExecMode::BoundedStaleness] {
            let cfg = RunConfig {
                n_clients: 4,
                profile,
                mode,
                participation: match mode {
                    ExecMode::Bsp => ParticipationPolicy::Fraction(0.5),
                    _ => ParticipationPolicy::Arrived,
                },
                staleness_bound: 2,
                ..Default::default()
            };
            assert_double_run_bitwise(&cfg, &format!("{mode:?}/{}", profile.name));
        }
    }
}

#[test]
fn double_run_is_bitwise_identical_on_the_cohort_path() {
    let cfg = RunConfig {
        n_clients: 4,
        profile: ClusterProfile::elastic_federated(),
        participation: ParticipationPolicy::Fraction(0.5),
        cohort: true,
        ..Default::default()
    };
    assert_double_run_bitwise(&cfg, "cohort/elastic");
}

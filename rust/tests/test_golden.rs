//! Cross-language numerics: rust native oracle vs python ref.py, pinned
//! through artifacts/golden.json (written by `make artifacts`).

use std::sync::Arc;
use stl_sgd::data::Dataset;
use stl_sgd::grad::{logreg::NativeLogreg, Oracle};
use stl_sgd::linalg::Matrix;
use stl_sgd::rng::golden::golden_logreg_inputs;
use stl_sgd::runtime::{artifacts_available, default_artifacts_dir};
use stl_sgd::util::json::Json;

fn native_case(seed: u64, n: usize, b: usize, d: usize, lam: f32) -> (Vec<Vec<f32>>, Vec<f32>) {
    let case = golden_logreg_inputs(seed, n, b, d);
    let mut grads = Vec::new();
    let mut losses = Vec::new();
    for i in 0..n {
        let rows: Vec<Vec<f32>> = (0..b)
            .map(|r| case.x[(i * b + r) * d..(i * b + r + 1) * d].to_vec())
            .collect();
        let ds = Arc::new(Dataset {
            x: Matrix::from_rows(&rows),
            y: case.y[i * b..(i + 1) * b].to_vec(),
            classes: 2,
            name: "golden".into(),
        });
        let oracle = NativeLogreg::new(ds, lam);
        let idx: Vec<usize> = (0..b).collect();
        let (g, l) = oracle.grad_minibatch(&case.theta[i * d..(i + 1) * d], &idx);
        grads.push(g);
        losses.push(l);
    }
    (grads, losses)
}

#[test]
fn native_oracle_matches_python_ref_golden_values() {
    if !artifacts_available() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    }
    let golden = Json::parse_file(&default_artifacts_dir().join("golden.json")).unwrap();
    let cases = golden.get("logreg").unwrap().as_arr().unwrap();
    assert!(cases.len() >= 3);

    for case in cases {
        let seed = case.get("seed").unwrap().as_usize().unwrap() as u64;
        let n = case.get("n").unwrap().as_usize().unwrap();
        let b = case.get("b").unwrap().as_usize().unwrap();
        let d = case.get("d").unwrap().as_usize().unwrap();
        let lam = case.get("lam").unwrap().as_f64().unwrap() as f32;

        let (grads, losses) = native_case(seed, n, b, d, lam);

        // losses
        let py_losses = case.get("losses").unwrap().as_f64_vec().unwrap();
        assert_eq!(py_losses.len(), n);
        for (i, (&py, rs)) in py_losses.iter().zip(&losses).enumerate() {
            assert!(
                (py - *rs as f64).abs() < 1e-5,
                "seed {seed} client {i}: python loss {py} vs rust {rs}"
            );
        }
        // first gradient head
        let head = case.get("grad_head").unwrap().as_f64_vec().unwrap();
        for (j, &py) in head.iter().enumerate() {
            let rs = grads[0][j] as f64;
            assert!(
                (py - rs).abs() < 1e-5,
                "seed {seed} grad[0][{j}]: python {py} vs rust {rs}"
            );
        }
        // per-client gradient norms
        let norms = case.get("grad_l2").unwrap().as_f64_vec().unwrap();
        for (i, &py) in norms.iter().enumerate() {
            let rs = stl_sgd::linalg::norm2(&grads[i]) as f64;
            assert!(
                (py - rs).abs() < 1e-4 * (1.0 + py),
                "seed {seed} client {i}: |g| python {py} vs rust {rs}"
            );
        }
    }
}

#[test]
fn golden_stream_matches_documented_layout() {
    // theta || x || y layout, labels in {-1, +1}
    let case = golden_logreg_inputs(7, 4, 8, 16);
    assert_eq!(case.theta.len(), 64);
    assert_eq!(case.x.len(), 512);
    assert_eq!(case.y.len(), 32);
    assert!(case.y.iter().all(|&v| v == 1.0 || v == -1.0));
}

//! Golden-schema gate: the CSV headers every downstream consumer (figure
//! scripts, sweep summaries, external plotting) keys on.
//!
//! The golden header lines are checked-in files under tests/goldens/, so
//! schema drift — adding, renaming, or reordering a column — fails this
//! test (and the `schema` CI stage, scripts/ci.sh) instead of silently
//! breaking plots downstream. To change a schema intentionally, update the
//! exporter *and* the golden in the same commit.

use stl_sgd::coordinator::{Trace, TracePoint};
use stl_sgd::simnet::{RoundStat, Timeline};

const TIMELINE_GOLDEN: &str = include_str!("goldens/timeline_header.txt");
const TRACE_GOLDEN: &str = include_str!("goldens/trace_header.txt");

fn header_of(path: &std::path::Path) -> String {
    let s = std::fs::read_to_string(path).unwrap();
    s.lines().next().unwrap_or_default().to_string()
}

#[test]
fn timeline_csv_header_matches_checked_in_golden() {
    let t = Timeline {
        rounds: vec![RoundStat {
            round: 0,
            steps: 4,
            k: 4,
            start: 0.0,
            compute_span: 1.0,
            comm_seconds: 0.5,
            max_barrier_wait: 0.0,
            mean_barrier_wait: 0.0,
            dropped: 0,
            participants: 2,
            joined: 0,
            left: 0,
            bytes_exact: 64,
            bytes_wire: 32,
            bytes_wire_down: 16,
            compression_ratio: 0.5,
            overlap_seconds: 0.0,
            critical_path_tier: 0,
            retries: 0,
            abandoned: 0,
            corrupt_dropped: 0,
        }],
        events: Vec::new(),
    };
    let dir = std::env::temp_dir().join("stl_sgd_schema_timeline");
    let path = dir.join("timeline.csv");
    t.write_csv(&path).unwrap();
    assert_eq!(
        header_of(&path),
        TIMELINE_GOLDEN.trim_end(),
        "timeline CSV header drifted from tests/goldens/timeline_header.txt"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn trace_csv_header_matches_checked_in_golden() {
    let t = Trace {
        algorithm: "schema".into(),
        points: vec![TracePoint {
            iter: 0,
            rounds: 0,
            epoch: 0.0,
            loss: 0.5,
            accuracy: 0.5,
            sim_seconds: 0.0,
            stage: 0,
            eta: 0.1,
            k: 1,
            realized_k: 0,
        }],
        ..Default::default()
    };
    let dir = std::env::temp_dir().join("stl_sgd_schema_trace");
    let path = dir.join("trace.csv");
    t.write_csv(&path).unwrap();
    assert_eq!(
        header_of(&path),
        TRACE_GOLDEN.trim_end(),
        "trace CSV header drifted from tests/goldens/trace_header.txt"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn goldens_include_the_compression_columns() {
    // The bytes axis is load-bearing for the compression sweeps, and the
    // overlap columns for the placement study: a golden "update" that
    // drops these columns must fail loudly here.
    for col in [
        "bytes_exact",
        "bytes_wire",
        "bytes_wire_down",
        "compression_ratio",
        "overlap_seconds",
        "critical_path_tier",
        "retries",
        "abandoned",
        "corrupt_dropped",
    ] {
        assert!(
            TIMELINE_GOLDEN.split(',').any(|c| c.trim() == col),
            "timeline golden lost column {col}"
        );
    }
}

//! Property-based tests on coordinator/algorithm invariants (from-scratch
//! harness in stl_sgd::testing since proptest is unavailable offline).

use stl_sgd::algo::{AlgoSpec, LrSchedule, Variant};
use stl_sgd::comm::{allreduce, Algorithm};
use stl_sgd::data::{partition, synth};
use stl_sgd::rng::Rng;
use stl_sgd::testing::{check, gen, PropConfig};

fn cfg(cases: usize) -> PropConfig {
    PropConfig {
        cases,
        seed: 0xABCD,
    }
}

#[test]
fn prop_all_collectives_agree_on_random_vectors() {
    check(cfg(64), "collectives-agree", |rng, _| {
        let n = gen::usize_in(rng, 1, 12);
        let d = gen::usize_in(rng, 1, 64);
        let base = gen::f32_matrix(rng, n, d, 2.0);
        let mut naive = base.clone();
        let mut ring = base.clone();
        let mut tree = base;
        allreduce::average(&mut naive, Algorithm::Naive);
        allreduce::average(&mut ring, Algorithm::Ring);
        allreduce::average(&mut tree, Algorithm::Tree);
        for i in 0..n {
            for j in 0..d {
                let (a, b, c) = (naive[i][j], ring[i][j], tree[i][j]);
                if (a - b).abs() > 1e-4 || (a - c).abs() > 1e-4 {
                    return Err(format!("n={n} d={d} [{i}][{j}]: {a} {b} {c}"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_averaging_replicates_and_preserves_mean() {
    check(cfg(64), "mean-preserved", |rng, _| {
        let n = gen::usize_in(rng, 2, 10);
        let d = gen::usize_in(rng, 1, 32);
        let models = gen::f32_matrix(rng, n, d, 1.0);
        let mean_before: f64 = models.iter().flatten().map(|&v| v as f64).sum::<f64>()
            / (n * d) as f64;
        let mut m = models;
        allreduce::average(&mut m, Algorithm::Ring);
        // all replicas identical
        for i in 1..n {
            if m[i] != m[0] {
                return Err(format!("replica {i} differs"));
            }
        }
        let mean_after: f64 =
            m.iter().flatten().map(|&v| v as f64).sum::<f64>() / (n * d) as f64;
        if (mean_before - mean_after).abs() > 1e-4 {
            return Err(format!("{mean_before} vs {mean_after}"));
        }
        Ok(())
    });
}

#[test]
fn prop_partitions_are_exact_covers() {
    check(cfg(32), "partition-cover", |rng, case| {
        let rows = gen::usize_in(rng, 10, 800);
        let classes = gen::usize_in(rng, 2, 10);
        let n_clients = gen::usize_in(rng, 1, 16);
        let s = [0.0, 25.0, 50.0, 100.0][case % 4];
        let ds = synth::cifar_like(case as u64, rows, 4, classes);
        let mut prng = Rng::new(case as u64);
        let shards = if case % 2 == 0 {
            partition::iid(&ds, n_clients, &mut prng)
        } else {
            partition::noniid(&ds, n_clients, s, &mut prng)
        };
        let mut seen = vec![false; rows];
        for sh in &shards {
            for &i in &sh.indices {
                if seen[i] {
                    return Err(format!("index {i} twice"));
                }
                seen[i] = true;
            }
        }
        if !seen.iter().all(|&b| b) {
            return Err("missing indices".into());
        }
        Ok(())
    });
}

#[test]
fn prop_phases_cover_budget_for_random_configs() {
    check(cfg(96), "phase-budget", |rng, case| {
        let variants = [
            Variant::SyncSgd,
            Variant::LbSgd,
            Variant::CrPsgd,
            Variant::LocalSgd,
            Variant::StlSc,
            Variant::StlNc1,
            Variant::StlNc2,
        ];
        let spec = AlgoSpec {
            variant: variants[case % variants.len()],
            eta1: 0.01 + rng.uniform() * 2.0,
            alpha: rng.uniform() * 1e-2,
            k1: 1.0 + rng.uniform() * 64.0,
            t1: gen::usize_in(rng, 1, 500) as u64,
            batch: gen::usize_in(rng, 1, 128),
            big_batch: gen::usize_in(rng, 64, 1024),
            batch_growth: 1.0 + rng.uniform() * 0.5,
            batch_cap: gen::usize_in(rng, 64, 1024),
            shard_size: gen::usize_in(rng, 16, 4000),
            iid: case % 2 == 0,
            inv_gamma: rng.uniform_f32(),
        };
        let budget = gen::usize_in(rng, 1, 50_000) as u64;
        let phases = spec.phases(budget);
        let total: u64 = phases.iter().map(|p| p.steps).sum();
        if total != budget {
            return Err(format!("{:?}: {total} != {budget}", spec.variant));
        }
        if !phases.iter().all(|p| p.comm_period >= 1 && p.batch >= 1) {
            return Err("bad phase fields".into());
        }
        Ok(())
    });
}

#[test]
fn prop_stl_sc_schedule_invariants() {
    // eta_s * T_s constant; k ratios match the growth law.
    check(cfg(48), "stl-sc-invariants", |rng, case| {
        let iid = case % 2 == 0;
        let spec = AlgoSpec {
            variant: Variant::StlSc,
            eta1: 0.05 + rng.uniform(),
            k1: 2.0 + rng.uniform() * 30.0,
            t1: gen::usize_in(rng, 50, 400) as u64,
            iid,
            ..Default::default()
        };
        let phases = spec.phases(spec.t1 * ((1 << 7) - 1));
        let target = spec.eta1 * spec.t1 as f64;
        for (i, p) in phases.iter().enumerate() {
            if i + 1 == phases.len() {
                break; // last may be truncated
            }
            let eta = match p.lr {
                LrSchedule::Const(e) => e,
                _ => return Err("non-const lr".into()),
            };
            if (eta * p.steps as f64 - target).abs() > 1e-6 * target {
                return Err(format!("stage {i}: eta*T = {}", eta * p.steps as f64));
            }
            // k_s = floor(k1 * g^(s-1))
            let g: f64 = if iid { 2.0 } else { std::f64::consts::SQRT_2 };
            let expect = (spec.k1 * g.powi(i as i32)).floor().max(1.0) as u64;
            if p.comm_period != expect {
                return Err(format!("stage {i}: k={} expect {expect}", p.comm_period));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_rng_split_streams_never_collide() {
    check(cfg(32), "rng-split", |rng, _| {
        let root = Rng::new(rng.next_u64());
        let mut a = root.split(1);
        let mut b = root.split(2);
        let matches = (0..32).filter(|_| a.next_u64() == b.next_u64()).count();
        if matches > 1 {
            return Err(format!("{matches} collisions"));
        }
        Ok(())
    });
}

#[test]
fn prop_comm_round_count_equals_phase_arithmetic() {
    use std::sync::Arc;
    use stl_sgd::coordinator::{run, NativeCompute, RunConfig};
    use stl_sgd::grad::logreg::NativeLogreg;

    check(cfg(12), "rounds-arith", |rng, case| {
        let n = gen::usize_in(rng, 2, 6);
        let ds = Arc::new(synth::a9a_like(case as u64, 128, 8));
        let oracle = Arc::new(NativeLogreg::new(ds.clone(), 0.01));
        let shards = partition::iid(&ds, n, &mut Rng::new(case as u64));
        let spec = AlgoSpec {
            variant: [Variant::LocalSgd, Variant::StlSc, Variant::StlNc2][case % 3],
            eta1: 0.2,
            k1: 1.0 + rng.uniform() * 10.0,
            t1: gen::usize_in(rng, 10, 60) as u64,
            batch: 4,
            iid: true,
            ..Default::default()
        };
        let budget = gen::usize_in(rng, 20, 400) as u64;
        let phases = spec.phases(budget);
        let expected: u64 = phases.iter().map(|p| p.comm_rounds()).sum();
        let mut engine = NativeCompute::new(oracle);
        let cfg = RunConfig {
            n_clients: n,
            eval_every_rounds: 10_000, // avoid eval cost
            ..Default::default()
        };
        let theta0 = vec![0.0f32; 8];
        let trace = run(&mut engine, &shards, &phases, &cfg, &theta0, "t");
        if trace.comm.rounds != expected {
            return Err(format!("{} != {expected}", trace.comm.rounds));
        }
        Ok(())
    });
}

#[test]
fn prop_masked_average_participants_match_naive_mean() {
    // Satellite contract for comm::average_masked: for every collective
    // and random (N, d, mask), participants end bit-identical to running
    // the same dense collective over just the participants (and, for the
    // Naive reference collective, bit-identical to the f64 mean over
    // participants); non-participants are untouched.
    check(cfg(96), "masked-average", |rng, case| {
        let alg = [Algorithm::Naive, Algorithm::Ring, Algorithm::Tree][case % 3];
        let n = gen::usize_in(rng, 1, 14);
        let d = gen::usize_in(rng, 1, 64);
        let models = gen::f32_matrix(rng, n, d, 2.0);
        let mask: Vec<bool> = (0..n).map(|_| rng.uniform() < 0.6).collect();
        let mut masked = models.clone();
        allreduce::average_masked(&mut masked, alg, &mask);

        // Dense reference over the extracted participants.
        let mut sub: Vec<Vec<f32>> = models
            .iter()
            .zip(&mask)
            .filter(|(_, &b)| b)
            .map(|(m, _)| m.clone())
            .collect();
        let m = sub.len();
        if m > 0 {
            allreduce::average(&mut sub, alg);
        }
        // Exact f64 mean over participants (what Naive must hit exactly
        // and the others to rounding error).
        let exact: Vec<f32> = (0..d)
            .map(|j| {
                let s: f64 = models
                    .iter()
                    .zip(&mask)
                    .filter(|(_, &b)| b)
                    .map(|(mm, _)| mm[j] as f64)
                    .sum();
                (s / m.max(1) as f64) as f32
            })
            .collect();

        let mut k = 0usize;
        for i in 0..n {
            if mask[i] {
                if masked[i] != sub[k] {
                    return Err(format!("{alg:?} n={n} d={d}: participant {i} not bit-identical"));
                }
                for j in 0..d {
                    let err = (masked[i][j] - exact[j]).abs();
                    let tol = if alg == Algorithm::Naive { 0.0 } else { 1e-4 };
                    if err > tol {
                        return Err(format!(
                            "{alg:?} n={n} d={d} m={m}: [{i}][{j}] off mean by {err}"
                        ));
                    }
                }
                k += 1;
            } else if masked[i] != models[i] {
                return Err(format!("{alg:?} n={n} d={d}: bystander {i} was touched"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_masked_average_all_ones_is_unmasked() {
    check(cfg(48), "masked-all-ones", |rng, case| {
        let alg = [Algorithm::Naive, Algorithm::Ring, Algorithm::Tree][case % 3];
        let n = gen::usize_in(rng, 1, 12);
        let d = gen::usize_in(rng, 1, 48);
        let base = gen::f32_matrix(rng, n, d, 1.5);
        let mut a = base.clone();
        let mut b = base;
        allreduce::average(&mut a, alg);
        allreduce::average_masked(&mut b, alg, &vec![true; n]);
        if a != b {
            return Err(format!("{alg:?} n={n} d={d}: all-ones mask diverged"));
        }
        Ok(())
    });
}

//! Cohort-sparse execution bit-identity suite (DESIGN.md §9).
//!
//! PR 7 restructures round state around the *sampled cohort*: a sparse
//! client store (last-synced snapshot + sampler position + lazy EF slot),
//! cohort-sized arenas, and the streaming `SparseSimNet` pricer. The
//! contract is that none of it changes *what is computed*: with
//! `cohort = true` the run must equal the dense `coordinator::run` path
//! bitwise — every trace point, timeline row, and accounting total —
//! across cluster preset x participation policy x compressor (plus
//! controllers, collectives, and downlink compression). Small-fleet
//! regressions ride along: a tiny `Fraction` never produces an empty
//! cohort by sampling (floor of one participant), and rounds emptied by
//! full churn-out are priced and counted, not crashed on.

use std::sync::Arc;
use stl_sgd::algo::{AlgoSpec, ControllerSpec, Variant};
use stl_sgd::comm::{Algorithm, CompressionSchedule};
use stl_sgd::coordinator::cohort::run_cohort_detailed;
use stl_sgd::coordinator::{run, NativeCompute, RunConfig, Trace};
use stl_sgd::data::{partition, Shard};
use stl_sgd::data::synth;
use stl_sgd::grad::logreg::NativeLogreg;
use stl_sgd::rng::Rng;
use stl_sgd::simnet::{ClusterProfile, ParticipationPolicy};

fn setup(n: usize) -> (Arc<NativeLogreg>, Vec<Shard>) {
    let ds = Arc::new(synth::a9a_like(2, 512, 16));
    let oracle = Arc::new(NativeLogreg::new(ds.clone(), 1e-3));
    let shards = partition::iid(&ds, n, &mut Rng::new(0));
    (oracle, shards)
}

fn spec() -> AlgoSpec {
    // Multi-stage STL-SC: stage anneals, anchor resets, phase-truncated
    // rounds — the schedule shapes the sampler fast-forward segments.
    AlgoSpec {
        variant: Variant::StlSc,
        eta1: 0.3,
        k1: 4.0,
        t1: 40,
        batch: 8,
        iid: true,
        ..Default::default()
    }
}

fn assert_traces_bitwise(a: &Trace, b: &Trace, tag: &str) {
    assert_eq!(a.points.len(), b.points.len(), "{tag}: point count");
    for (pa, pb) in a.points.iter().zip(&b.points) {
        assert_eq!(pa.iter, pb.iter, "{tag}: iter");
        assert_eq!(pa.rounds, pb.rounds, "{tag}: rounds @ iter {}", pa.iter);
        assert_eq!(pa.epoch.to_bits(), pb.epoch.to_bits(), "{tag}: epoch @ iter {}", pa.iter);
        assert_eq!(pa.loss.to_bits(), pb.loss.to_bits(), "{tag}: loss @ iter {}", pa.iter);
        assert_eq!(
            pa.accuracy.to_bits(),
            pb.accuracy.to_bits(),
            "{tag}: accuracy @ iter {}",
            pa.iter
        );
        assert_eq!(
            pa.sim_seconds.to_bits(),
            pb.sim_seconds.to_bits(),
            "{tag}: sim_seconds @ iter {}",
            pa.iter
        );
        assert_eq!(pa.stage, pb.stage, "{tag}: stage @ iter {}", pa.iter);
        assert_eq!(pa.eta.to_bits(), pb.eta.to_bits(), "{tag}: eta @ iter {}", pa.iter);
        assert_eq!(pa.k, pb.k, "{tag}: k @ iter {}", pa.iter);
        assert_eq!(pa.realized_k, pb.realized_k, "{tag}: realized_k @ iter {}", pa.iter);
    }
    assert_eq!(a.comm, b.comm, "{tag}: comm stats");
    assert_eq!(
        a.clock.compute_seconds.to_bits(),
        b.clock.compute_seconds.to_bits(),
        "{tag}: compute clock"
    );
    assert_eq!(
        a.clock.comm_seconds.to_bits(),
        b.clock.comm_seconds.to_bits(),
        "{tag}: comm clock"
    );
    assert_eq!(a.timeline, b.timeline, "{tag}: timeline");
    assert_eq!(a.total_iters, b.total_iters, "{tag}: total iters");
    assert_eq!(a.stopped_early, b.stopped_early, "{tag}: stop flag");
}

/// Dense run vs the same config routed through the cohort path; returns
/// both traces for extra per-test assertions.
fn run_both(cfg: &RunConfig, tag: &str) -> (Trace, Trace) {
    assert!(!cfg.cohort, "run_both flips the flag itself");
    let (oracle, shards) = setup(cfg.n_clients);
    let theta0 = vec![0.0f32; 16];
    let phases = spec().phases(240);
    let mut e1 = NativeCompute::new(oracle.clone());
    let dense = run(&mut e1, &shards, &phases, cfg, &theta0, "x");
    let mut cfg2 = cfg.clone();
    cfg2.cohort = true;
    let mut e2 = NativeCompute::new(oracle);
    let cohort = run(&mut e2, &shards, &phases, &cfg2, &theta0, "x");
    assert_traces_bitwise(&dense, &cohort, tag);
    (dense, cohort)
}

#[test]
fn cohort_equals_dense_identity_all_on_every_preset() {
    for profile in ClusterProfile::presets() {
        let cfg = RunConfig {
            n_clients: 4,
            profile,
            ..Default::default()
        };
        run_both(&cfg, &format!("identity/all/{}", profile.name));
    }
}

#[test]
fn cohort_equals_dense_across_policies_and_presets() {
    for profile in ClusterProfile::presets() {
        for policy in [
            ParticipationPolicy::Arrived,
            ParticipationPolicy::Fraction(0.5),
            ParticipationPolicy::Fraction(0.25),
        ] {
            let cfg = RunConfig {
                n_clients: 4,
                profile,
                participation: policy,
                ..Default::default()
            };
            run_both(&cfg, &format!("identity/{policy:?}/{}", profile.name));
        }
    }
}

#[test]
fn cohort_equals_dense_across_compressors() {
    for profile in [
        ClusterProfile::homogeneous(),
        ClusterProfile::flaky_federated(),
        ClusterProfile::elastic_federated(),
    ] {
        for policy in [
            ParticipationPolicy::All,
            ParticipationPolicy::Arrived,
            ParticipationPolicy::Fraction(0.5),
        ] {
            for comp in ["topk", "qsgd", "topk-anneal", "qsgd-anneal"] {
                let cfg = RunConfig {
                    n_clients: 4,
                    profile,
                    participation: policy,
                    compression: CompressionSchedule::parse(comp).unwrap(),
                    ..Default::default()
                };
                run_both(&cfg, &format!("{comp}/{policy:?}/{}", profile.name));
            }
        }
    }
}

#[test]
fn cohort_equals_dense_across_controllers_collectives_and_downlink() {
    for controller in [
        ControllerSpec::CommRatio { target: 1.0 },
        ControllerSpec::BarrierAware { frac: 0.05 },
    ] {
        for collective in [Algorithm::Naive, Algorithm::Ring, Algorithm::Tree] {
            let cfg = RunConfig {
                n_clients: 6, // non-power-of-two: exercises the tree tail fold
                profile: ClusterProfile::heavy_tail_stragglers(),
                participation: ParticipationPolicy::Fraction(0.5),
                collective,
                controller,
                compression: CompressionSchedule::parse("topk").unwrap(),
                down_compression: CompressionSchedule::parse("qsgd"),
                ..Default::default()
            };
            run_both(&cfg, &format!("topk/frac/{controller:?}/{collective:?}"));
        }
    }
}

#[test]
fn tiny_fraction_small_fleet_never_samples_an_empty_cohort() {
    // Satellite regression at the coordinator level: `--participation
    // 0.001` on a 4-client fleet floors to one sampled client per round
    // (never zero), and the cohort path pins the dense trajectory.
    let cfg = RunConfig {
        n_clients: 4,
        participation: ParticipationPolicy::Fraction(0.001),
        ..Default::default()
    };
    let (dense, cohort) = run_both(&cfg, "frac-0.001/homogeneous");
    assert_eq!(dense.comm.empty_rounds, 0);
    assert_eq!(cohort.comm.empty_rounds, 0);
    assert!(dense.timeline.rounds.iter().all(|r| r.participants == 1));
    assert!(dense.comm.rounds > 0);
}

#[test]
fn full_churn_out_prices_empty_rounds_with_accounting() {
    // A fleet that drains (certain leave, no rejoin) must keep running:
    // empty rounds are priced, counted in `empty_rounds`, and leave the
    // server model untouched — identically on both paths.
    let mut profile = ClusterProfile::homogeneous();
    profile.leave_prob = 1.0;
    profile.name = "drain";
    let cfg = RunConfig {
        n_clients: 4,
        profile,
        participation: ParticipationPolicy::Fraction(0.5),
        ..Default::default()
    };
    let (dense, cohort) = run_both(&cfg, "drain/frac-0.5");
    assert!(dense.comm.empty_rounds > 0, "the drained fleet never emptied a round");
    assert_eq!(dense.comm.empty_rounds, cohort.comm.empty_rounds);
    // Post-drain evals all see the frozen server model.
    let last = dense.points.last().unwrap();
    assert!(last.loss.is_finite());
}

#[test]
fn unbounded_budget_matches_a_budget_that_never_evicts() {
    // budget = 0 (unbounded) and budget >= fleet are both lossless and
    // must agree bitwise with each other and the dense path.
    let base = RunConfig {
        n_clients: 4,
        profile: ClusterProfile::elastic_federated(),
        participation: ParticipationPolicy::Fraction(0.5),
        compression: CompressionSchedule::parse("topk").unwrap(),
        ..Default::default()
    };
    let (_, unbounded) = run_both(&base, "budget-0");
    let mut roomy = base.clone();
    roomy.cohort = true;
    roomy.cohort_budget = 64;
    let (oracle, shards) = setup(4);
    let theta0 = vec![0.0f32; 16];
    let phases = spec().phases(240);
    let mut engine = NativeCompute::new(oracle);
    let budgeted = run(&mut engine, &shards, &phases, &roomy, &theta0, "x");
    assert_traces_bitwise(&unbounded, &budgeted, "budget-64");
}

#[test]
fn tight_budget_evicts_and_still_converges() {
    // A budget below the distinct-participant count forces evictions;
    // lossy ones reset state to theta0 (counted), the run stays finite
    // and the store never holds more than budget + cohort entries.
    let (oracle, shards) = setup(6);
    let theta0 = vec![0.0f32; 16];
    let phases = spec().phases(240);
    let cfg = RunConfig {
        n_clients: 6,
        profile: ClusterProfile::flaky_federated(),
        participation: ParticipationPolicy::Fraction(0.34), // ceil(2.04) = 3 of 6 per round
        cohort: true,
        cohort_budget: 2,
        ..Default::default()
    };
    let mut engine = NativeCompute::new(oracle);
    let (trace, report) =
        run_cohort_detailed(&mut engine, &shards, &phases, &cfg, &theta0, "x");
    assert!(trace.final_loss().is_finite());
    assert!(report.store.materialized > 2, "budget never stressed");
    assert!(
        report.store.evicted_clean + report.store.evicted_lossy > 0,
        "no evictions under a tight budget"
    );
    assert!(report.live_entries <= 2 + report.peak_cohort);
}

#[test]
fn scale_smoke_memory_tracks_the_cohort_not_the_fleet() {
    // In-process million-light version of examples/million_clients.rs:
    // 50k clients at 0.1% participation — state stays within the distinct
    // participants (cohort-proportional), nowhere near the fleet.
    let ds = Arc::new(synth::a9a_like(2, 512, 16));
    let oracle = Arc::new(NativeLogreg::new(ds.clone(), 1e-3));
    let shards = partition::iid(&ds, 16, &mut Rng::new(0));
    let theta0 = vec![0.0f32; 16];
    let spec = AlgoSpec {
        variant: Variant::LocalSgd,
        eta1: 0.3,
        alpha: 1e-3,
        k1: 4.0,
        batch: 8,
        iid: true,
        ..Default::default()
    };
    let phases = spec.phases(32);
    let cfg = RunConfig {
        n_clients: 50_000,
        participation: ParticipationPolicy::Fraction(0.001),
        cohort: true,
        eval_every_rounds: u64::MAX,
        eval_accuracy: false,
        timeline_detail: stl_sgd::simnet::Detail::Off,
        ..Default::default()
    };
    let mut engine = NativeCompute::new(oracle);
    let (trace, report) =
        run_cohort_detailed(&mut engine, &shards, &phases, &cfg, &theta0, "x");
    assert_eq!(trace.comm.rounds, 8);
    assert_eq!(report.peak_cohort, 50); // ceil(0.001 * 50_000)
    let ceiling = 8 * 50;
    assert!(report.live_entries <= ceiling, "{}", report.live_entries);
    assert!(report.priced_clients <= ceiling, "{}", report.priced_clients);
    assert!(report.live_entries >= 50);
}

//! Algorithm-level integration tests: every algorithm converges on the
//! convex workload; the paper's qualitative orderings hold on fixed seeds.

use stl_sgd::algo::{AlgoSpec, Variant};
use stl_sgd::bench_support::workloads::{self, compute_f_star};
use stl_sgd::config::{ExperimentConfig, Workload};
use stl_sgd::coordinator::Trace;

fn convex_cfg(variant: Variant, iid: bool, steps: u64) -> ExperimentConfig {
    ExperimentConfig {
        workload: Workload::LogregTest,
        iid,
        s_percent: 50.0,
        n_clients: 4,
        total_steps: steps,
        seed: 11,
        algo: AlgoSpec {
            variant,
            eta1: 0.5,
            alpha: 1e-3,
            k1: 8.0,
            t1: 200,
            batch: 8,
            big_batch: 32,
            batch_growth: 1.2,
            batch_cap: 32,
            iid,
            inv_gamma: 0.05,
            ..Default::default()
        },
        collective: stl_sgd::comm::Algorithm::Ring,
        eval_every_rounds: 1,
        engine: "native".into(),
        // cluster/participation defaults: homogeneous fleet, policy `all`.
        ..ExperimentConfig::default()
    }
}

fn run(cfg: &ExperimentConfig) -> Trace {
    workloads::run_experiment(cfg).unwrap()
}

#[test]
fn every_algorithm_converges_convex_iid() {
    for v in [
        Variant::SyncSgd,
        Variant::LbSgd,
        Variant::CrPsgd,
        Variant::LocalSgd,
        Variant::StlSc,
        Variant::StlNc1,
        Variant::StlNc2,
    ] {
        let trace = run(&convex_cfg(v, true, 3000));
        let start = trace.points[0].loss;
        let end = trace.best_loss();
        assert!(
            end < start * 0.8,
            "{v:?}: start {start} best {end} (no convergence)"
        );
        assert!(trace.final_loss().is_finite(), "{v:?} diverged");
    }
}

#[test]
fn every_algorithm_converges_convex_noniid() {
    for v in [Variant::SyncSgd, Variant::LocalSgd, Variant::StlSc] {
        let trace = run(&convex_cfg(v, false, 3000));
        assert!(
            trace.best_loss() < trace.points[0].loss * 0.85,
            "{v:?} Non-IID did not converge"
        );
    }
}

#[test]
fn stl_sc_uses_fewer_rounds_than_local_sgd_to_same_gap() {
    // The paper's headline (Table 1): STL-SGD^sc reaches the target gap in
    // fewer communication rounds than Local SGD with the same budget.
    let f_star = compute_f_star(Workload::LogregTest, 11, 400);
    let gap = 2e-3;

    let local = run(&convex_cfg(Variant::LocalSgd, true, 6000));
    let stl = run(&convex_cfg(Variant::StlSc, true, 6000));

    let r_local = local.rounds_to_gap(f_star, gap);
    let r_stl = stl.rounds_to_gap(f_star, gap);
    assert!(r_local.is_some(), "local never reached gap");
    assert!(r_stl.is_some(), "stl never reached gap");
    assert!(
        r_stl.unwrap() <= r_local.unwrap(),
        "stl {:?} rounds vs local {:?}",
        r_stl,
        r_local
    );
}

#[test]
fn local_sgd_uses_fewer_rounds_than_sync_sgd() {
    let f_star = compute_f_star(Workload::LogregTest, 11, 400);
    let gap = 2e-3;
    let sync = run(&convex_cfg(Variant::SyncSgd, true, 6000));
    let local = run(&convex_cfg(Variant::LocalSgd, true, 6000));
    let r_sync = sync.rounds_to_gap(f_star, gap).expect("sync reached");
    let r_local = local.rounds_to_gap(f_star, gap).expect("local reached");
    assert!(
        r_local < r_sync,
        "local {r_local} rounds should beat sync {r_sync}"
    );
}

#[test]
fn noniid_needs_more_rounds_than_iid_for_local_sgd() {
    // Heterogeneity slows Local SGD at fixed k — the reason the paper's
    // Non-IID k grows slower.
    let f_star = compute_f_star(Workload::LogregTest, 11, 400);
    let gap = 2e-3;
    let iid_cfg = convex_cfg(Variant::LocalSgd, true, 6000);
    let mut non_cfg = convex_cfg(Variant::LocalSgd, false, 6000);
    non_cfg.s_percent = 0.0; // maximally heterogeneous
    let iid = run(&iid_cfg);
    let non = run(&non_cfg);
    let (Some(r_iid), r_non) = (iid.rounds_to_gap(f_star, gap), non.rounds_to_gap(f_star, gap))
    else {
        panic!("iid never reached gap");
    };
    match r_non {
        None => {} // non-iid failed to reach at all: consistent
        Some(r) => assert!(
            r >= r_iid,
            "non-iid should need >= rounds ({r} vs {r_iid})"
        ),
    }
}

#[test]
fn mlp_nonconvex_algorithms_learn() {
    for v in [Variant::LocalSgd, Variant::StlNc1, Variant::StlNc2] {
        let cfg = ExperimentConfig {
            workload: Workload::MlpTest,
            iid: true,
            n_clients: 4,
            total_steps: 600,
            seed: 5,
            algo: AlgoSpec {
                variant: v,
                eta1: 0.3,
                alpha: 0.0,
                k1: 5.0,
                t1: 100,
                batch: 8,
                iid: true,
                inv_gamma: 0.01,
                ..Default::default()
            },
            collective: stl_sgd::comm::Algorithm::Ring,
            eval_every_rounds: 2,
            engine: "threaded".into(),
            s_percent: 0.0,
            ..ExperimentConfig::default()
        };
        let trace = run(&cfg);
        assert!(
            trace.final_accuracy() > trace.points[0].accuracy + 0.1,
            "{v:?}: acc {} -> {}",
            trace.points[0].accuracy,
            trace.final_accuracy()
        );
    }
}

#[test]
fn trace_csv_and_json_outputs_written() {
    let trace = run(&convex_cfg(Variant::StlSc, true, 500));
    let dir = std::env::temp_dir().join(format!("stl_trace_{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let csv = dir.join("t.csv");
    trace.write_csv(&csv).unwrap();
    let text = std::fs::read_to_string(&csv).unwrap();
    assert!(text.starts_with("iter,rounds,epoch,loss"));
    assert!(text.lines().count() > 3);
    let j = stl_sgd::util::json::Json::parse(&trace.to_json().to_string()).unwrap();
    assert!(j.get("points").unwrap().as_arr().unwrap().len() > 2);
    std::fs::remove_dir_all(&dir).ok();
}

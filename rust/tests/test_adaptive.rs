//! Adaptive communication-period controllers (DESIGN.md §5) and the
//! wasted-compute fix.
//!
//! Three contracts are pinned here:
//! * the default `Stagewise` controller realizes exactly the fixed
//!   phase-arithmetic schedule — trajectories *and* simnet timelines are
//!   bit-for-bit identical to an independent replay, across every cluster
//!   preset;
//! * adaptive controllers are deterministic: identical `(config, seed)`
//!   yields the identical realized-k sequence;
//! * under masked participation, compute for clients known to sit the
//!   round out is skipped — oracle-call counts drop in proportion to the
//!   sampled fraction with bit-identical trajectories (counting oracle).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use stl_sgd::algo::{AlgoSpec, ControllerSpec, Variant};
use stl_sgd::bench_support::workloads;
use stl_sgd::config::{ExperimentConfig, Workload};
use stl_sgd::coordinator::{run, NativeCompute, RunConfig, ThreadedCompute};
use stl_sgd::data::{partition, synth, Dataset};
use stl_sgd::grad::{logreg::NativeLogreg, Oracle};
use stl_sgd::rng::Rng;
use stl_sgd::sim::{ComputeModel, NetworkModel};
use stl_sgd::simnet::{ClusterProfile, Detail, ParticipationPolicy, SimNet};

fn base_cfg(profile: ClusterProfile, variant: Variant, seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.workload = Workload::LogregTest; // a9a_like(seed, 64, 16): dim 16
    cfg.engine = "native".into();
    cfg.n_clients = 4;
    cfg.total_steps = 230;
    cfg.seed = seed;
    cfg.cluster = profile;
    cfg.algo = AlgoSpec {
        variant,
        eta1: 0.3,
        k1: 7.0,
        t1: 40,
        batch: 8,
        iid: true,
        ..Default::default()
    };
    cfg
}

#[test]
fn stagewise_controller_realizes_phase_arithmetic_bit_for_bit_on_every_preset() {
    // (a) Rounds match the scheduled Phase arithmetic exactly, and (b) an
    // independent raw SimNet fed that schedule reconstructs the identical
    // timeline — so the controller-driven loop places every comm point
    // exactly where the fixed-k loop did, on every cluster preset.
    for profile in ClusterProfile::presets() {
        for variant in [Variant::LocalSgd, Variant::StlSc] {
            let cfg = base_cfg(profile, variant, 19);
            assert_eq!(cfg.controller, ControllerSpec::Stagewise, "default");
            let trace = workloads::run_experiment(&cfg).unwrap();
            let phases = cfg.algo.phases(cfg.total_steps);
            let scheduled: u64 = phases.iter().map(|p| p.comm_rounds()).sum();
            assert_eq!(
                trace.comm.rounds, scheduled,
                "{} {variant:?}: realized rounds != scheduled",
                profile.name
            );
            assert_eq!(trace.comm.local_steps, cfg.total_steps, "{}", profile.name);
            assert_eq!(trace.comm.client_rounds(4), scheduled * 4);

            let mut sim = SimNet::new(
                profile,
                NetworkModel::default(),
                ComputeModel::default(),
                cfg.collective,
                cfg.n_clients,
                16,
                cfg.seed,
                Detail::Rounds,
            );
            for p in &phases {
                let k = p.comm_period.max(1);
                for _ in 0..p.steps / k {
                    sim.price_round_scheduled(k, p.batch, k);
                }
                if p.steps % k > 0 {
                    sim.price_round_scheduled(p.steps % k, p.batch, k);
                }
            }
            assert_eq!(
                sim.take_timeline(),
                trace.timeline,
                "{} {variant:?}: timeline drifted from the fixed schedule",
                profile.name
            );
            // The realized-k trace column reports the triggering round.
            for p in &trace.points[1..] {
                assert!(p.realized_k >= 1 && p.realized_k <= p.k, "iter {}", p.iter);
            }
        }
    }
}

#[test]
fn adaptive_controllers_are_deterministic_in_config_and_seed() {
    for spec in [
        ControllerSpec::CommRatio { target: 1.0 },
        ControllerSpec::BarrierAware { frac: 0.05 },
    ] {
        for profile in [
            ClusterProfile::heavy_tail_stragglers(),
            ClusterProfile::elastic_federated(),
        ] {
            let mk = || {
                let mut cfg = base_cfg(profile, Variant::LocalSgd, 29);
                cfg.controller = spec;
                if profile.leave_prob > 0.0 {
                    cfg.participation = ParticipationPolicy::Arrived;
                }
                workloads::run_experiment(&cfg).unwrap()
            };
            let (a, b) = (mk(), mk());
            let ks = |t: &stl_sgd::coordinator::Trace| {
                t.timeline.rounds.iter().map(|r| (r.k, r.steps)).collect::<Vec<_>>()
            };
            assert_eq!(ks(&a), ks(&b), "{} {spec:?}: realized-k sequence", profile.name);
            assert_eq!(a.timeline, b.timeline, "{} {spec:?}", profile.name);
            for (pa, pb) in a.points.iter().zip(&b.points) {
                assert_eq!(pa.loss.to_bits(), pb.loss.to_bits(), "{spec:?}");
            }
        }
    }
}

#[test]
fn adaptive_controllers_stretch_periods_and_cut_simulated_time_under_stragglers() {
    // The closed loop in action: on the straggler-bound profile both
    // adaptive controllers sync less often than the fixed schedule and
    // finish the same step budget in less simulated time.
    let fixed = workloads::run_experiment(&base_cfg(
        ClusterProfile::heavy_tail_stragglers(),
        Variant::LocalSgd,
        7,
    ))
    .unwrap();
    for spec in [
        ControllerSpec::CommRatio { target: 1.0 },
        ControllerSpec::BarrierAware { frac: 0.05 },
    ] {
        let mut cfg = base_cfg(ClusterProfile::heavy_tail_stragglers(), Variant::LocalSgd, 7);
        cfg.controller = spec;
        let adaptive = workloads::run_experiment(&cfg).unwrap();
        assert_eq!(adaptive.total_iters, fixed.total_iters);
        assert!(
            adaptive.comm.rounds < fixed.comm.rounds,
            "{spec:?}: {} !< {}",
            adaptive.comm.rounds,
            fixed.comm.rounds
        );
        assert!(
            adaptive.comm.mean_realized_k() > fixed.comm.mean_realized_k(),
            "{spec:?} never stretched the period"
        );
        assert!(
            adaptive.timeline.rounds.iter().any(|r| r.k > 7),
            "{spec:?}: timeline k column never exceeded the schedule"
        );
        assert!(
            adaptive.clock.total() < fixed.clock.total(),
            "{spec:?}: {} !< {} simulated seconds",
            adaptive.clock.total(),
            fixed.clock.total()
        );
    }
}

#[test]
fn boundary_coinciding_with_k_multiple_counts_one_round() {
    // 120 steps at k = 40: the third k-multiple lands exactly on the
    // phase boundary — the loop must comm once there, not twice, and the
    // realized accounting must agree with the scheduled arithmetic.
    let mut cfg = base_cfg(ClusterProfile::homogeneous(), Variant::LocalSgd, 3);
    cfg.total_steps = 120;
    cfg.algo.k1 = 40.0;
    let trace = workloads::run_experiment(&cfg).unwrap();
    assert_eq!(trace.comm.rounds, 3);
    assert_eq!(trace.comm.local_steps, 120);
    assert!((trace.comm.mean_realized_k() - 40.0).abs() < 1e-12);
    assert!(trace.timeline.rounds.iter().all(|r| r.steps == 40 && r.k == 40));

    // Ragged tail: 130 steps -> 4 rounds, the last realizing only 10 of
    // the commanded 40.
    cfg.total_steps = 130;
    let trace = workloads::run_experiment(&cfg).unwrap();
    assert_eq!(trace.comm.rounds, 4);
    assert_eq!(trace.comm.local_steps, 130);
    let last = trace.timeline.rounds.last().unwrap();
    assert_eq!((last.steps, last.k), (10, 40));
    let last_pt = trace.points.last().unwrap();
    assert_eq!((last_pt.realized_k, last_pt.k), (10, 40));
}

/// Oracle wrapper that counts gradient calls (the wasted-compute metric).
struct CountingOracle {
    inner: Arc<dyn Oracle>,
    calls: AtomicU64,
}

impl Oracle for CountingOracle {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn grad_minibatch(&self, theta: &[f32], indices: &[usize]) -> (Vec<f32>, f32) {
        self.calls.fetch_add(1, Ordering::Relaxed);
        self.inner.grad_minibatch(theta, indices)
    }

    fn full_loss(&self, theta: &[f32]) -> f64 {
        self.inner.full_loss(theta)
    }

    fn full_accuracy(&self, theta: &[f32]) -> f64 {
        self.inner.full_accuracy(theta)
    }

    fn dataset(&self) -> &Arc<Dataset> {
        self.inner.dataset()
    }
}

#[test]
fn fraction_sampling_skips_unsampled_compute_with_bit_identical_trajectory() {
    let ds = Arc::new(synth::a9a_like(1, 512, 16));
    let base_oracle: Arc<dyn Oracle> = Arc::new(NativeLogreg::new(ds.clone(), 1e-3));
    let shards = partition::iid(&ds, 4, &mut Rng::new(0));
    let spec = AlgoSpec {
        variant: Variant::LocalSgd,
        eta1: 0.3,
        alpha: 1e-3,
        k1: 5.0,
        batch: 8,
        ..Default::default()
    };
    let phases = spec.phases(200);
    let theta0 = vec![0.0f32; 16];
    let run_once = |skip: bool| {
        let counting = Arc::new(CountingOracle {
            inner: base_oracle.clone(),
            calls: AtomicU64::new(0),
        });
        let mut engine = NativeCompute::new(counting.clone());
        let cfg = RunConfig {
            n_clients: 4,
            participation: ParticipationPolicy::Fraction(0.5),
            skip_inactive_compute: skip,
            ..Default::default()
        };
        let trace = run(&mut engine, &shards, &phases, &cfg, &theta0, "t");
        (trace, counting.calls.load(Ordering::Relaxed))
    };
    let (full, full_calls) = run_once(false);
    let (skipped, skip_calls) = run_once(true);
    assert_eq!(full.points.len(), skipped.points.len());
    for (a, b) in full.points.iter().zip(&skipped.points) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "iter {}", a.iter);
    }
    assert_eq!(full.timeline, skipped.timeline);
    // Oracle calls drop in proportion to the sampled fraction:
    // ceil(0.5 * 4) = 2 of 4 clients compute each round under the
    // fault-free homogeneous profile.
    assert_eq!(full_calls, 200 * 4);
    assert_eq!(skip_calls, 200 * 2);
}

#[test]
fn threaded_engine_matches_native_with_compute_skipping() {
    // The skip path dispatches a subset of clients to the worker pool;
    // the masked trajectory must stay identical to the sequential engine.
    let ds = Arc::new(synth::a9a_like(2, 256, 12));
    let oracle = Arc::new(NativeLogreg::new(ds.clone(), 1e-3));
    let shards = partition::iid(&ds, 4, &mut Rng::new(0));
    let spec = AlgoSpec {
        variant: Variant::LocalSgd,
        eta1: 0.3,
        alpha: 1e-3,
        k1: 5.0,
        batch: 8,
        ..Default::default()
    };
    let phases = spec.phases(150);
    let cfg = RunConfig {
        n_clients: 4,
        participation: ParticipationPolicy::Fraction(0.5),
        ..Default::default()
    };
    assert!(cfg.skip_inactive_compute, "skipping is the default");
    let theta0 = vec![0.0f32; 12];
    let mut native = NativeCompute::new(oracle.clone());
    let a = run(&mut native, &shards, &phases, &cfg, &theta0, "native");
    let mut threaded = ThreadedCompute::new(oracle, 4);
    let b = run(&mut threaded, &shards, &phases, &cfg, &theta0, "threaded");
    assert_eq!(a.points.len(), b.points.len());
    for (pa, pb) in a.points.iter().zip(&b.points) {
        assert_eq!(pa.loss.to_bits(), pb.loss.to_bits(), "iter {}", pa.iter);
    }
    assert_eq!(a.timeline, b.timeline);
}

#[test]
fn skipping_composes_with_adaptive_control_and_churn() {
    // All three features at once — elastic churn, fraction sampling with
    // compute skipping, and an adaptive controller — stay deterministic
    // and converge.
    let mk = || {
        let mut cfg = base_cfg(ClusterProfile::elastic_federated(), Variant::LocalSgd, 41);
        cfg.total_steps = 480;
        cfg.participation = ParticipationPolicy::Fraction(0.5);
        cfg.controller = ControllerSpec::BarrierAware { frac: 0.05 };
        workloads::run_experiment(&cfg).unwrap()
    };
    let (a, b) = (mk(), mk());
    assert_eq!(a.timeline, b.timeline);
    for (pa, pb) in a.points.iter().zip(&b.points) {
        assert_eq!(pa.loss.to_bits(), pb.loss.to_bits());
    }
    assert!(a.final_loss().is_finite());
    assert!(a.comm.partial_rounds > 0, "sampling never produced a subset round");
}

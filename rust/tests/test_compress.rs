//! Gradient-compression pricing (DESIGN.md §6).
//!
//! Contracts pinned here:
//! * the `identity` compressor is bit-for-bit identical to the
//!   pre-compression code path — trajectories *and* simnet timelines —
//!   across every cluster preset and participation policy (the PR-4
//!   analogue of the `all`-participation and `stagewise`-controller
//!   invariants from PRs 2–3);
//! * `topk` / `qsgd` shrink `bytes_wire` (timeline CSV and CommStats) by
//!   exactly the configured, data-independent payload ratio while leaving
//!   compute spans untouched;
//! * error-feedback residuals of non-participants are frozen, not
//!   decayed, under partial participation;
//! * compressed runs are deterministic in `(config, seed)` (QSGD's
//!   stochastic rounding draws from dedicated per-client streams) and
//!   still converge on the convex workload.

use stl_sgd::algo::{AlgoSpec, Variant};
use stl_sgd::bench_support::workloads;
use stl_sgd::comm::compress::{average_compressed, CompressorSpec, EfState};
use stl_sgd::comm::Algorithm;
use stl_sgd::config::{ExperimentConfig, Workload};
use stl_sgd::rng::Rng;
use stl_sgd::simnet::{ClusterProfile, ParticipationPolicy};

fn base_cfg(profile: ClusterProfile, seed: u64) -> ExperimentConfig {
    let mut cfg = ExperimentConfig::default();
    cfg.workload = Workload::LogregTest; // a9a_like(seed, 64, 16): dim 16
    cfg.engine = "native".into();
    cfg.n_clients = 4;
    cfg.total_steps = 240;
    cfg.seed = seed;
    cfg.cluster = profile;
    cfg.algo = AlgoSpec {
        variant: Variant::StlSc,
        eta1: 0.3,
        k1: 4.0,
        t1: 40,
        batch: 8,
        iid: true,
        ..Default::default()
    };
    cfg
}

#[test]
fn identity_compressor_is_bit_for_bit_on_every_preset_and_policy() {
    // Acceptance gate: `--compressor identity` reproduces the
    // pre-compression trajectories and timelines exactly. The default
    // config (no compression key) is the pre-PR behaviour the rest of the
    // suite pins, so equality against it, bitwise, across every cluster
    // preset and policy, is the invariant.
    for profile in ClusterProfile::presets() {
        for policy in [ParticipationPolicy::All, ParticipationPolicy::Arrived] {
            let mut legacy = base_cfg(profile, 19);
            legacy.participation = policy;
            let mut explicit = legacy.clone();
            explicit.apply_override("compressor", "identity").unwrap();
            assert!(explicit.compression.is_always_identity());
            let a = workloads::run_experiment(&legacy).unwrap();
            let b = workloads::run_experiment(&explicit).unwrap();
            assert_eq!(a.points.len(), b.points.len(), "{} {policy:?}", profile.name);
            for (pa, pb) in a.points.iter().zip(&b.points) {
                assert_eq!(
                    pa.loss.to_bits(),
                    pb.loss.to_bits(),
                    "{} {policy:?} iter {}",
                    profile.name,
                    pa.iter
                );
            }
            assert_eq!(a.timeline, b.timeline, "{} {policy:?}", profile.name);
            assert_eq!(a.comm, b.comm, "{} {policy:?}", profile.name);
            // Identity's wire ledger is the exact ledger.
            assert_eq!(b.comm.wire_bytes_per_client, b.comm.bytes_per_client);
            assert!(b
                .timeline
                .rounds
                .iter()
                .all(|r| r.bytes_wire == r.bytes_exact && r.compression_ratio == 1.0));
        }
    }
}

#[test]
fn topk_and_qsgd_cut_wire_bytes_by_the_configured_ratio() {
    // dim 16: topk frac 0.25 keeps 4 entries -> 32B of 64B (ratio 0.5);
    // qsgd 4-bit -> 4B scale + 8B levels = 12B of 64B (ratio 0.1875).
    for (name, knob_key, knob_val, expect) in [
        ("topk", "topk_frac", "0.25", CompressorSpec::TopK { frac: 0.25 }),
        ("qsgd", "compress_bits", "4", CompressorSpec::Qsgd { bits: 4 }),
    ] {
        let mut cfg = base_cfg(ClusterProfile::homogeneous(), 7);
        cfg.apply_override("compressor", name).unwrap();
        cfg.apply_override(knob_key, knob_val).unwrap();
        let exact = workloads::run_experiment(&base_cfg(ClusterProfile::homogeneous(), 7)).unwrap();
        let compressed = workloads::run_experiment(&cfg).unwrap();
        let ratio = expect.payload_ratio(16);
        assert!(ratio < 1.0, "{name}");
        assert_eq!(compressed.comm.rounds, exact.comm.rounds, "{name}");
        assert_eq!(
            compressed.comm.bytes_per_client, exact.comm.bytes_per_client,
            "{name}: the exact ledger is compression-independent"
        );
        for r in &compressed.timeline.rounds {
            assert_eq!(r.compression_ratio, ratio, "{name} round {}", r.round);
            assert!(r.bytes_wire < r.bytes_exact, "{name} round {}", r.round);
            assert_eq!(
                r.bytes_wire,
                stl_sgd::comm::allreduce::bytes_per_client_payload(
                    Algorithm::Ring,
                    r.participants as usize,
                    expect.payload_bytes(16),
                ),
                "{name} round {}",
                r.round
            );
        }
        assert!(
            (compressed.comm.compression_ratio() - ratio).abs() < 1e-12,
            "{name}: run ledger ratio {} != {ratio}",
            compressed.comm.compression_ratio()
        );
        // Cheaper wire bytes mean cheaper simulated communication.
        assert!(
            compressed.clock.comm_seconds < exact.clock.comm_seconds,
            "{name}"
        );
        // Compute pricing is untouched by the payload.
        assert_eq!(
            compressed.clock.compute_seconds.to_bits(),
            exact.clock.compute_seconds.to_bits(),
            "{name}"
        );
        // Lossy averaging changes the trajectory but still converges.
        assert!(
            exact.points.iter().zip(&compressed.points).any(|(a, b)| a.loss != b.loss),
            "{name}: compression never changed the trajectory"
        );
        assert!(
            compressed.final_loss() < compressed.points[0].loss * 0.9,
            "{name}: compressed run failed to converge ({} -> {})",
            compressed.points[0].loss,
            compressed.final_loss()
        );
    }
}

#[test]
fn anneal_schedule_relaxes_ratio_across_stages() {
    // StlSc grows stages; topk-anneal doubles the kept fraction per stage
    // until exact. The timeline ratio must be non-decreasing over rounds
    // and reach 1.0 in the late stages of a long-enough run.
    let mut cfg = base_cfg(ClusterProfile::homogeneous(), 11);
    cfg.total_steps = 1200;
    cfg.apply_override("compressor", "topk-anneal").unwrap();
    cfg.apply_override("topk_frac", "0.25").unwrap();
    let trace = workloads::run_experiment(&cfg).unwrap();
    let ratios: Vec<f64> = trace.timeline.rounds.iter().map(|r| r.compression_ratio).collect();
    assert!(ratios.windows(2).all(|w| w[0] <= w[1]), "ratio must anneal monotonically");
    assert!(*ratios.first().unwrap() < 1.0, "early stages must compress");
    assert_eq!(*ratios.last().unwrap(), 1.0, "late stages must be exact");
    assert!(trace.final_loss() < trace.points[0].loss * 0.9);
}

#[test]
fn compressed_runs_are_deterministic_in_config_and_seed() {
    for (compressor, profile) in [
        ("qsgd", ClusterProfile::heavy_tail_stragglers()),
        ("topk", ClusterProfile::elastic_federated()),
    ] {
        let mk = || {
            let mut cfg = base_cfg(profile, 29);
            cfg.apply_override("compressor", compressor).unwrap();
            if profile.leave_prob > 0.0 {
                cfg.participation = ParticipationPolicy::Arrived;
            }
            workloads::run_experiment(&cfg).unwrap()
        };
        let (a, b) = (mk(), mk());
        assert_eq!(a.timeline, b.timeline, "{compressor} {}", profile.name);
        for (pa, pb) in a.points.iter().zip(&b.points) {
            assert_eq!(
                pa.loss.to_bits(),
                pb.loss.to_bits(),
                "{compressor} {} iter {}",
                profile.name,
                pa.iter
            );
        }
    }
}

#[test]
fn compression_composes_with_partial_participation_and_stays_finite() {
    // All the PR-2/3/4 features at once: flaky cluster, arrived policy,
    // adaptive controller, qsgd compression.
    let mut cfg = base_cfg(ClusterProfile::flaky_federated(), 41);
    cfg.total_steps = 480;
    cfg.participation = ParticipationPolicy::Arrived;
    cfg.apply_override("controller", "comm-ratio").unwrap();
    cfg.apply_override("compressor", "qsgd").unwrap();
    let trace = workloads::run_experiment(&cfg).unwrap();
    assert!(trace.comm.partial_rounds > 0, "flaky never produced a subset round");
    assert!(trace.final_loss().is_finite());
    assert!(trace.comm.wire_bytes_per_client < trace.comm.bytes_per_client);
}

#[test]
fn nonparticipant_residuals_are_frozen_not_decayed() {
    // Satellite contract: compose `average_masked`-style partial
    // participation with compression — a client outside the round's mask
    // must keep its residual bit-for-bit (a parameter server cannot touch
    // state it never heard from), while participants' residuals update.
    let d = 32;
    let n = 4;
    let spec = CompressorSpec::TopK { frac: 0.25 };
    let mut rng = Rng::new(3);
    let mut models: Vec<Vec<f32>> =
        (0..n).map(|_| (0..d).map(|_| rng.normal_f32()).collect()).collect();
    let reference = vec![0.0f32; d];
    let mut ef = EfState::new(n, d, 9);

    // Round 1: everyone participates; every residual becomes non-zero
    // (top-k drops 24 of 32 coordinates of a dense normal delta).
    average_compressed(&mut models, &reference, Algorithm::Ring, spec, &mut ef, &[true; n]);
    let after_round1: Vec<Vec<f32>> = (0..n).map(|i| ef.residual(i).to_vec()).collect();
    for (i, r) in after_round1.iter().enumerate() {
        assert!(r.iter().any(|&e| e != 0.0), "client {i} residual empty after round 1");
    }

    // Local drift before round 2, so participants transmit something new.
    let reference2 = models[0].clone();
    for m in models.iter_mut() {
        for v in m.iter_mut() {
            *v += rng.normal_f32() * 0.1;
        }
    }
    let frozen_model = models[1].clone();

    // Round 2: client 1 sits out.
    let mask = [true, false, true, true];
    average_compressed(&mut models, &reference2, Algorithm::Ring, spec, &mut ef, &mask);
    assert_eq!(
        ef.residual(1),
        after_round1[1].as_slice(),
        "non-participant residual must be frozen bit-for-bit"
    );
    assert_eq!(models[1], frozen_model, "non-participant replica untouched");
    for i in [0usize, 2, 3] {
        assert_ne!(
            ef.residual(i),
            after_round1[i].as_slice(),
            "participant {i} residual should have updated"
        );
    }
}

#[test]
fn frozen_stream_resumes_identically_after_absence() {
    // A qsgd client that skips rounds must transmit from the exact stream
    // position it left at — absent rounds consume none of its draws.
    let d = 16;
    let spec = CompressorSpec::Qsgd { bits: 4 };
    let delta: Vec<f32> = {
        let mut r = Rng::new(5);
        (0..d).map(|_| r.normal_f32()).collect()
    };
    let mk_models = || vec![delta.clone(), delta.clone()];
    let reference = vec![0.0f32; d];

    // Fleet A: client 1 participates in rounds 1 and 2 — its stream makes
    // draws #1 and #2, each over the same fresh delta.
    let mut ef_a = EfState::new(2, d, 77);
    for _ in 0..2 {
        let mut m = mk_models();
        average_compressed(&mut m, &reference, Algorithm::Naive, spec, &mut ef_a, &[true; 2]);
    }

    // Fleet B: client 1 sits out round 1, then participates twice with
    // the same fresh deltas. If absence consumed any of its draws, its
    // first participation would quantize with different uniforms and the
    // residual after two participations would diverge from fleet A's.
    let mut ef_b = EfState::new(2, d, 77);
    let mut mb = mk_models();
    average_compressed(&mut mb, &reference, Algorithm::Naive, spec, &mut ef_b, &[true, false]);
    for _ in 0..2 {
        let mut mb = mk_models();
        average_compressed(&mut mb, &reference, Algorithm::Naive, spec, &mut ef_b, &[true; 2]);
    }
    assert_eq!(
        ef_a.residual(1),
        ef_b.residual(1),
        "absent rounds must not advance the quantization stream"
    );
}

//! simnet <-> closed-form calibration equivalence and determinism.
//!
//! The contract (see rust/src/simnet/mod.rs): under the zero-variance
//! `homogeneous` profile the discrete-event engine must reproduce the
//! closed-form `sim::SimClock` totals *bit-for-bit* — same repeated
//! -addition folds, same allreduce pricing — across every collective and
//! any (N, d, comm_period). And any profile, however random, must be a
//! pure function of the seed: identical configs yield identical event
//! timelines.

use stl_sgd::algo::{AlgoSpec, Phase, Variant};
use stl_sgd::bench_support::workloads;
use stl_sgd::comm::Algorithm;
use stl_sgd::config::{ExperimentConfig, Workload};
use stl_sgd::sim::{ComputeModel, NetworkModel, SimClock};
use stl_sgd::simnet::{ClusterProfile, Detail, SimNet};
use stl_sgd::testing::{check, gen, PropConfig};

const ALGS: [Algorithm; 3] = [Algorithm::Naive, Algorithm::Ring, Algorithm::Tree];

/// The closed-form clock for a round schedule, accumulated in the same
/// order the coordinator prices rounds.
fn closed_form_clock(
    phases: &[Phase],
    n: usize,
    d: usize,
    net: &NetworkModel,
    cm: &ComputeModel,
    alg: Algorithm,
) -> SimClock {
    let mut clock = SimClock::default();
    let comm = net.allreduce_seconds(alg, n, d);
    for p in phases {
        let k = p.comm_period.max(1);
        let full = p.steps / k;
        let rem = p.steps % k;
        for _ in 0..full {
            clock.add_compute(cm.round_compute_seconds(p.batch, d, k));
            clock.add_comm(comm);
        }
        if rem > 0 {
            clock.add_compute(cm.round_compute_seconds(p.batch, d, rem));
            clock.add_comm(comm);
        }
    }
    clock
}

#[test]
fn homogeneous_engine_matches_closed_form_bit_for_bit() {
    // Property sweep: random (N, d, k, rounds) per case, one collective
    // per case, engine totals must equal the closed-form totals exactly.
    let net = NetworkModel::default();
    let cm = ComputeModel::default();
    check(
        PropConfig {
            cases: 48,
            seed: 0x51,
        },
        "simnet homogeneous == closed form",
        |rng, case| {
            let alg = ALGS[case % 3];
            let n = gen::usize_in(rng, 2, 33);
            let d = gen::usize_in(rng, 8, 2048);
            let k = gen::usize_in(rng, 1, 12) as u64;
            let batch = gen::usize_in(rng, 1, 64);
            let rounds = gen::usize_in(rng, 1, 6);
            let mut sim = SimNet::new(
                ClusterProfile::homogeneous(),
                net,
                cm,
                alg,
                n,
                d,
                case as u64,
                Detail::Rounds,
            );
            let mut actual = SimClock::default();
            let mut expect = SimClock::default();
            for _ in 0..rounds {
                let rt = sim.price_round(k, batch);
                actual.add_compute(rt.compute_span);
                actual.add_comm(rt.comm_seconds);
                expect.add_compute(cm.round_compute_seconds(batch, d, k));
                expect.add_comm(net.allreduce_seconds(alg, n, d));
                if rt.max_barrier_wait != 0.0 || rt.dropped != 0 {
                    return Err(format!(
                        "homogeneous round has waits/drops: {rt:?} (alg={alg:?} n={n})"
                    ));
                }
            }
            if actual.compute_seconds.to_bits() != expect.compute_seconds.to_bits() {
                return Err(format!(
                    "compute {} != {} (alg={alg:?} n={n} d={d} k={k})",
                    actual.compute_seconds, expect.compute_seconds
                ));
            }
            if actual.comm_seconds.to_bits() != expect.comm_seconds.to_bits() {
                return Err(format!(
                    "comm {} != {} (alg={alg:?} n={n} d={d} k={k})",
                    actual.comm_seconds, expect.comm_seconds
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn homogeneous_end_to_end_totals_match_closed_form() {
    // Whole-coordinator equivalence: a real experiment priced through
    // simnet lands on exactly the closed-form clock, for both a fixed
    // comm period and the stagewise STL schedule, on every collective.
    for variant in [Variant::LocalSgd, Variant::StlSc] {
        for alg in ALGS {
            let mut cfg = ExperimentConfig::default();
            cfg.workload = Workload::LogregTest;
            cfg.engine = "native".into();
            cfg.n_clients = 6; // non-power-of-two: exercises the Tree fix
            cfg.collective = alg;
            cfg.total_steps = 230;
            cfg.algo = AlgoSpec {
                variant,
                eta1: 0.3,
                k1: 7.0,
                t1: 40,
                batch: 8,
                iid: true,
                ..Default::default()
            };
            let trace = workloads::run_experiment(&cfg).unwrap();
            let mut spec = cfg.algo.clone();
            spec.shard_size = 64 / cfg.n_clients; // a9a_like(seed, 64, 16) iid shards
            let phases = spec.phases(cfg.total_steps);
            let expect = closed_form_clock(
                &phases,
                cfg.n_clients,
                16,
                &NetworkModel::default(),
                &ComputeModel::default(),
                alg,
            );
            assert_eq!(
                trace.clock.compute_seconds.to_bits(),
                expect.compute_seconds.to_bits(),
                "{variant:?}/{alg:?} compute"
            );
            assert_eq!(
                trace.clock.comm_seconds.to_bits(),
                expect.comm_seconds.to_bits(),
                "{variant:?}/{alg:?} comm"
            );
        }
    }
}

#[test]
fn same_seed_same_timeline_for_every_profile() {
    for profile in ClusterProfile::presets() {
        let mk = || {
            let mut cfg = ExperimentConfig::default();
            cfg.workload = Workload::LogregTest;
            cfg.engine = "native".into();
            cfg.n_clients = 4;
            cfg.total_steps = 120;
            cfg.seed = 13;
            cfg.cluster = profile;
            cfg.algo = AlgoSpec {
                variant: Variant::LocalSgd,
                eta1: 0.3,
                k1: 6.0,
                batch: 8,
                ..Default::default()
            };
            workloads::run_experiment(&cfg).unwrap()
        };
        let (a, b) = (mk(), mk());
        assert_eq!(a.timeline, b.timeline, "{} timeline", profile.name);
        assert_eq!(
            a.clock.total().to_bits(),
            b.clock.total().to_bits(),
            "{} clock",
            profile.name
        );
        for (pa, pb) in a.points.iter().zip(&b.points) {
            assert_eq!(pa.loss, pb.loss, "{} iter {}", profile.name, pa.iter);
            assert_eq!(
                pa.sim_seconds.to_bits(),
                pb.sim_seconds.to_bits(),
                "{} iter {}",
                profile.name,
                pa.iter
            );
        }
    }
}

#[test]
fn different_seeds_price_differently_under_noise() {
    let price = |seed: u64| {
        let mut sim = SimNet::new(
            ClusterProfile::heavy_tail_stragglers(),
            NetworkModel::default(),
            ComputeModel::default(),
            Algorithm::Ring,
            8,
            1000,
            seed,
            Detail::Off,
        );
        let mut total = 0.0;
        for _ in 0..20 {
            let rt = sim.price_round(8, 16);
            total += rt.compute_span + rt.comm_seconds;
        }
        total
    };
    assert_ne!(price(1).to_bits(), price(2).to_bits());
}

#[test]
fn stragglers_make_frequent_sync_costlier() {
    // Under heavy-tail stragglers, SyncSGD (a barrier every step) must
    // pay more simulated time than Local SGD (k = 8) for the same step
    // budget — the effect the closed-form span model cannot express.
    let run = |variant: Variant, k1: f64| {
        let mut cfg = ExperimentConfig::default();
        cfg.workload = Workload::LogregTest;
        cfg.engine = "native".into();
        cfg.n_clients = 8;
        cfg.total_steps = 240;
        cfg.cluster = ClusterProfile::heavy_tail_stragglers();
        cfg.algo = AlgoSpec {
            variant,
            eta1: 0.3,
            k1,
            batch: 8,
            ..Default::default()
        };
        workloads::run_experiment(&cfg).unwrap()
    };
    let sync = run(Variant::SyncSgd, 1.0);
    let local = run(Variant::LocalSgd, 8.0);
    assert!(sync.comm.rounds > local.comm.rounds);
    assert!(
        sync.clock.total() > local.clock.total(),
        "sync={} local={}",
        sync.clock.total(),
        local.clock.total()
    );
    // Barrier-wait accounting is populated under heterogeneity.
    assert!(local.timeline.total_max_barrier_wait() > 0.0);
}

// ---------------------------------------------------------------------------
// Elastic membership / partial participation (PR 2)
// ---------------------------------------------------------------------------

use stl_sgd::simnet::{ParticipationPolicy, RoundStat, Timeline};

#[test]
fn policy_all_trajectory_is_profile_invariant_bit_for_bit() {
    // The PR-1 invariant, now stated as the `all` participation policy:
    // the cluster profile changes *when* things happen, never *what* is
    // computed — so under policy `all` every profile (including the new
    // churny elastic-federated) walks bit-for-bit the same trajectory as
    // the homogeneous calibration run.
    let run_with = |profile| {
        let mut cfg = ExperimentConfig::default();
        cfg.workload = Workload::LogregTest;
        cfg.engine = "native".into();
        cfg.n_clients = 4;
        cfg.total_steps = 160;
        cfg.seed = 3;
        cfg.cluster = profile;
        cfg.algo = AlgoSpec {
            variant: Variant::LocalSgd,
            eta1: 0.3,
            k1: 5.0,
            batch: 8,
            ..Default::default()
        };
        workloads::run_experiment(&cfg).unwrap()
    };
    let reference = run_with(ClusterProfile::homogeneous());
    for profile in ClusterProfile::presets() {
        let trace = run_with(profile);
        assert_eq!(trace.points.len(), reference.points.len(), "{}", profile.name);
        for (a, b) in reference.points.iter().zip(&trace.points) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "{} iter {}", profile.name, a.iter);
        }
        // Policy `all` never reports partial rounds, whatever the faults.
        assert_eq!(trace.comm.partial_rounds, 0, "{}", profile.name);
        assert!(
            trace.timeline.rounds.iter().all(|r| r.participants == 4),
            "{}: participants dipped under policy all",
            profile.name
        );
    }
}

#[test]
fn same_seed_same_participation_masks_and_timelines() {
    // Identical (config, seed) must yield identical participation-mask
    // sequences — at the raw engine level and end-to-end through the
    // coordinator — for the faulty and churny profiles alike.
    for profile in [
        ClusterProfile::flaky_federated(),
        ClusterProfile::elastic_federated(),
    ] {
        let mk = || {
            SimNet::new(
                profile,
                NetworkModel::default(),
                ComputeModel::default(),
                Algorithm::Ring,
                8,
                1000,
                17,
                Detail::Rounds,
            )
            .with_policy(ParticipationPolicy::Arrived)
        };
        let (mut a, mut b) = (mk(), mk());
        for r in 0..120 {
            let (sa, pa) = a.price_round_masked(6, 16);
            let (sb, pb) = b.price_round_masked(6, 16);
            assert_eq!(pa, pb, "{} round {r} mask", profile.name);
            assert_eq!(sa, sb, "{} round {r} stat", profile.name);
            assert_eq!(sa.participants as usize, pa.count(), "{} round {r}", profile.name);
        }
        assert_eq!(a.timeline, b.timeline, "{}", profile.name);

        let run_once = || {
            let mut cfg = ExperimentConfig::default();
            cfg.workload = Workload::LogregTest;
            cfg.engine = "native".into();
            cfg.n_clients = 6;
            cfg.total_steps = 240;
            cfg.seed = 29;
            cfg.cluster = profile;
            cfg.participation = ParticipationPolicy::Arrived;
            cfg.algo = AlgoSpec {
                variant: Variant::LocalSgd,
                eta1: 0.3,
                k1: 4.0,
                batch: 8,
                ..Default::default()
            };
            workloads::run_experiment(&cfg).unwrap()
        };
        let (x, y) = (run_once(), run_once());
        assert_eq!(x.timeline, y.timeline, "{}", profile.name);
        for (px, py) in x.points.iter().zip(&y.points) {
            assert_eq!(px.loss.to_bits(), py.loss.to_bits(), "{}", profile.name);
        }
    }
}

#[test]
fn elastic_federated_churns_and_arrived_averages_subsets() {
    let mut cfg = ExperimentConfig::default();
    cfg.workload = Workload::LogregTest;
    cfg.engine = "native".into();
    cfg.n_clients = 6;
    cfg.total_steps = 480;
    cfg.seed = 11;
    cfg.cluster = ClusterProfile::elastic_federated();
    cfg.participation = ParticipationPolicy::Arrived;
    cfg.algo = AlgoSpec {
        variant: Variant::LocalSgd,
        eta1: 0.3,
        k1: 4.0,
        batch: 8,
        ..Default::default()
    };
    let trace = workloads::run_experiment(&cfg).unwrap();
    assert!(trace.timeline.total_left() > 0, "no churn departures in 120 rounds");
    assert!(trace.timeline.total_joined() > 0, "no churn rejoins in 120 rounds");
    assert!(trace.comm.partial_rounds > 0, "no partial rounds");
    assert_eq!(
        trace.comm.partial_rounds,
        trace.timeline.partial_rounds(6),
        "CommStats and timeline disagree on partial rounds"
    );
    assert_eq!(
        trace.comm.participant_client_rounds,
        trace.timeline.total_participants()
    );
    assert!(trace.final_loss().is_finite());
}

#[test]
fn arrived_subsets_visible_in_timeline_csv() {
    // Acceptance: under `arrived` the flaky-federated profile shows
    // rounds averaging strict subsets, visible in the timeline CSV's
    // participation columns.
    let mut cfg = ExperimentConfig::default();
    cfg.workload = Workload::LogregTest;
    cfg.engine = "native".into();
    cfg.n_clients = 6;
    cfg.total_steps = 480;
    cfg.seed = 7;
    cfg.cluster = ClusterProfile::flaky_federated();
    cfg.participation = ParticipationPolicy::Arrived;
    cfg.algo = AlgoSpec {
        variant: Variant::LocalSgd,
        eta1: 0.3,
        k1: 4.0,
        batch: 8,
        ..Default::default()
    };
    let trace = workloads::run_experiment(&cfg).unwrap();
    let dir = std::env::temp_dir().join("stl_sgd_partial_csv_test");
    let path = dir.join("timeline.csv");
    trace.write_timeline_csv(&path).unwrap();
    let s = std::fs::read_to_string(&path).unwrap();
    let mut lines = s.lines();
    let header: Vec<&str> = lines.next().unwrap().split(',').collect();
    let p_col = header.iter().position(|&h| h == "participants").unwrap();
    let mut saw_strict_subset = false;
    for (row, stat) in lines.zip(&trace.timeline.rounds) {
        let fields: Vec<&str> = row.split(',').collect();
        let participants: u32 = fields[p_col].parse().unwrap();
        assert_eq!(participants, stat.participants);
        saw_strict_subset |= participants < 6;
    }
    assert!(saw_strict_subset, "CSV never shows a strict-subset round");
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn timeline_csv_schema_golden() {
    // Golden-file guard for the exporter: exact header and an exact
    // fixed-value row, so schema or float-format drift is caught by
    // tier-1 instead of by example scripts.
    let t = Timeline {
        rounds: vec![RoundStat {
            round: 0,
            steps: 10,
            k: 12,
            start: 0.0,
            compute_span: 0.5,
            comm_seconds: 0.25,
            max_barrier_wait: 0.125,
            mean_barrier_wait: 0.0625,
            dropped: 1,
            participants: 3,
            joined: 1,
            left: 2,
            bytes_exact: 4000,
            bytes_wire: 1000,
            bytes_wire_down: 500,
            compression_ratio: 0.25,
            overlap_seconds: 0.0,
            critical_path_tier: 0,
            retries: 0,
            abandoned: 0,
            corrupt_dropped: 0,
        }],
        events: Vec::new(),
    };
    let dir = std::env::temp_dir().join("stl_sgd_csv_golden_test");
    let path = dir.join("golden.csv");
    t.write_csv(&path).unwrap();
    let s = std::fs::read_to_string(&path).unwrap();
    let golden = "round,steps,k,start,compute_span,comm_seconds,barrier_wait_max,\
                  barrier_wait_mean,dropped,participants,joined,left,\
                  bytes_exact,bytes_wire,bytes_wire_down,compression_ratio,end,\
                  overlap_seconds,critical_path_tier,retries,abandoned,corrupt_dropped\n\
                  0,10,12,0.000000e0,5.000000e-1,2.500000e-1,1.250000e-1,6.250000e-2,\
                  1,3,1,2,4000,1000,500,0.2500,7.500000e-1,0.000000e0,0,0,0,0\n";
    assert_eq!(s, golden);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn timeline_csv_fixed_seed_engine_row_matches_closed_form() {
    // A fixed-seed row produced by the engine itself: under the
    // zero-variance homogeneous profile every field is the closed-form
    // value, so the expected CSV line can be reconstructed exactly.
    let net = NetworkModel::default();
    let cm = ComputeModel::default();
    let mut sim = SimNet::new(
        ClusterProfile::homogeneous(),
        net,
        cm,
        Algorithm::Ring,
        4,
        1000,
        7,
        Detail::Rounds,
    );
    sim.price_round(5, 32);
    let dir = std::env::temp_dir().join("stl_sgd_csv_engine_row_test");
    let path = dir.join("row.csv");
    sim.timeline.write_csv(&path).unwrap();
    let s = std::fs::read_to_string(&path).unwrap();
    let compute = cm.round_compute_seconds(32, 1000, 5);
    let comm = net.allreduce_seconds(Algorithm::Ring, 4, 1000);
    let expect_row = format!(
        "0,5,5,{:.6e},{:.6e},{:.6e},{:.6e},{:.6e},0,4,0,0,6000,6000,3000,1.0000,{:.6e},0.000000e0,0,0,0,0",
        0.0,
        compute,
        comm,
        0.0,
        0.0,
        compute + comm,
    );
    assert_eq!(s.lines().nth(1).unwrap(), expect_row);
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fraction_sampling_is_deterministic_and_fleetwide_over_time() {
    // Fixed-fraction sampling: same seed, same sampled subsets; over many
    // rounds every client is sampled at least once (no starvation).
    let mk = || {
        SimNet::new(
            ClusterProfile::homogeneous(),
            NetworkModel::default(),
            ComputeModel::default(),
            Algorithm::Ring,
            8,
            1000,
            23,
            Detail::Rounds,
        )
        .with_policy(ParticipationPolicy::Fraction(0.25))
    };
    let (mut a, mut b) = (mk(), mk());
    let mut seen = [false; 8];
    for _ in 0..64 {
        let (_, pa) = a.price_round_masked(4, 16);
        let (_, pb) = b.price_round_masked(4, 16);
        assert_eq!(pa, pb);
        assert_eq!(pa.count(), 2, "ceil(0.25 * 8)");
        for i in pa.indices() {
            seen[i] = true;
        }
    }
    assert!(seen.iter().all(|&s| s), "a client was never sampled: {seen:?}");
}

//! simnet <-> closed-form calibration equivalence and determinism.
//!
//! The contract (see rust/src/simnet/mod.rs): under the zero-variance
//! `homogeneous` profile the discrete-event engine must reproduce the
//! closed-form `sim::SimClock` totals *bit-for-bit* — same repeated
//! -addition folds, same allreduce pricing — across every collective and
//! any (N, d, comm_period). And any profile, however random, must be a
//! pure function of the seed: identical configs yield identical event
//! timelines.

use stl_sgd::algo::{AlgoSpec, Phase, Variant};
use stl_sgd::bench_support::workloads;
use stl_sgd::comm::Algorithm;
use stl_sgd::config::{ExperimentConfig, Workload};
use stl_sgd::sim::{ComputeModel, NetworkModel, SimClock};
use stl_sgd::simnet::{ClusterProfile, Detail, SimNet};
use stl_sgd::testing::{check, gen, PropConfig};

const ALGS: [Algorithm; 3] = [Algorithm::Naive, Algorithm::Ring, Algorithm::Tree];

/// The closed-form clock for a round schedule, accumulated in the same
/// order the coordinator prices rounds.
fn closed_form_clock(
    phases: &[Phase],
    n: usize,
    d: usize,
    net: &NetworkModel,
    cm: &ComputeModel,
    alg: Algorithm,
) -> SimClock {
    let mut clock = SimClock::default();
    let comm = net.allreduce_seconds(alg, n, d);
    for p in phases {
        let k = p.comm_period.max(1);
        let full = p.steps / k;
        let rem = p.steps % k;
        for _ in 0..full {
            clock.add_compute(cm.round_compute_seconds(p.batch, d, k));
            clock.add_comm(comm);
        }
        if rem > 0 {
            clock.add_compute(cm.round_compute_seconds(p.batch, d, rem));
            clock.add_comm(comm);
        }
    }
    clock
}

#[test]
fn homogeneous_engine_matches_closed_form_bit_for_bit() {
    // Property sweep: random (N, d, k, rounds) per case, one collective
    // per case, engine totals must equal the closed-form totals exactly.
    let net = NetworkModel::default();
    let cm = ComputeModel::default();
    check(
        PropConfig {
            cases: 48,
            seed: 0x51,
        },
        "simnet homogeneous == closed form",
        |rng, case| {
            let alg = ALGS[case % 3];
            let n = gen::usize_in(rng, 2, 33);
            let d = gen::usize_in(rng, 8, 2048);
            let k = gen::usize_in(rng, 1, 12) as u64;
            let batch = gen::usize_in(rng, 1, 64);
            let rounds = gen::usize_in(rng, 1, 6);
            let mut sim = SimNet::new(
                ClusterProfile::homogeneous(),
                net,
                cm,
                alg,
                n,
                d,
                case as u64,
                Detail::Rounds,
            );
            let mut actual = SimClock::default();
            let mut expect = SimClock::default();
            for _ in 0..rounds {
                let rt = sim.price_round(k, batch);
                actual.add_compute(rt.compute_span);
                actual.add_comm(rt.comm_seconds);
                expect.add_compute(cm.round_compute_seconds(batch, d, k));
                expect.add_comm(net.allreduce_seconds(alg, n, d));
                if rt.max_barrier_wait != 0.0 || rt.dropped != 0 {
                    return Err(format!(
                        "homogeneous round has waits/drops: {rt:?} (alg={alg:?} n={n})"
                    ));
                }
            }
            if actual.compute_seconds.to_bits() != expect.compute_seconds.to_bits() {
                return Err(format!(
                    "compute {} != {} (alg={alg:?} n={n} d={d} k={k})",
                    actual.compute_seconds, expect.compute_seconds
                ));
            }
            if actual.comm_seconds.to_bits() != expect.comm_seconds.to_bits() {
                return Err(format!(
                    "comm {} != {} (alg={alg:?} n={n} d={d} k={k})",
                    actual.comm_seconds, expect.comm_seconds
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn homogeneous_end_to_end_totals_match_closed_form() {
    // Whole-coordinator equivalence: a real experiment priced through
    // simnet lands on exactly the closed-form clock, for both a fixed
    // comm period and the stagewise STL schedule, on every collective.
    for variant in [Variant::LocalSgd, Variant::StlSc] {
        for alg in ALGS {
            let mut cfg = ExperimentConfig::default();
            cfg.workload = Workload::LogregTest;
            cfg.engine = "native".into();
            cfg.n_clients = 6; // non-power-of-two: exercises the Tree fix
            cfg.collective = alg;
            cfg.total_steps = 230;
            cfg.algo = AlgoSpec {
                variant,
                eta1: 0.3,
                k1: 7.0,
                t1: 40,
                batch: 8,
                iid: true,
                ..Default::default()
            };
            let trace = workloads::run_experiment(&cfg).unwrap();
            let mut spec = cfg.algo.clone();
            spec.shard_size = 64 / cfg.n_clients; // a9a_like(seed, 64, 16) iid shards
            let phases = spec.phases(cfg.total_steps);
            let expect = closed_form_clock(
                &phases,
                cfg.n_clients,
                16,
                &NetworkModel::default(),
                &ComputeModel::default(),
                alg,
            );
            assert_eq!(
                trace.clock.compute_seconds.to_bits(),
                expect.compute_seconds.to_bits(),
                "{variant:?}/{alg:?} compute"
            );
            assert_eq!(
                trace.clock.comm_seconds.to_bits(),
                expect.comm_seconds.to_bits(),
                "{variant:?}/{alg:?} comm"
            );
        }
    }
}

#[test]
fn same_seed_same_timeline_for_every_profile() {
    for profile in ClusterProfile::presets() {
        let mk = || {
            let mut cfg = ExperimentConfig::default();
            cfg.workload = Workload::LogregTest;
            cfg.engine = "native".into();
            cfg.n_clients = 4;
            cfg.total_steps = 120;
            cfg.seed = 13;
            cfg.cluster = profile;
            cfg.algo = AlgoSpec {
                variant: Variant::LocalSgd,
                eta1: 0.3,
                k1: 6.0,
                batch: 8,
                ..Default::default()
            };
            workloads::run_experiment(&cfg).unwrap()
        };
        let (a, b) = (mk(), mk());
        assert_eq!(a.timeline, b.timeline, "{} timeline", profile.name);
        assert_eq!(
            a.clock.total().to_bits(),
            b.clock.total().to_bits(),
            "{} clock",
            profile.name
        );
        for (pa, pb) in a.points.iter().zip(&b.points) {
            assert_eq!(pa.loss, pb.loss, "{} iter {}", profile.name, pa.iter);
            assert_eq!(
                pa.sim_seconds.to_bits(),
                pb.sim_seconds.to_bits(),
                "{} iter {}",
                profile.name,
                pa.iter
            );
        }
    }
}

#[test]
fn different_seeds_price_differently_under_noise() {
    let price = |seed: u64| {
        let mut sim = SimNet::new(
            ClusterProfile::heavy_tail_stragglers(),
            NetworkModel::default(),
            ComputeModel::default(),
            Algorithm::Ring,
            8,
            1000,
            seed,
            Detail::Off,
        );
        let mut total = 0.0;
        for _ in 0..20 {
            let rt = sim.price_round(8, 16);
            total += rt.compute_span + rt.comm_seconds;
        }
        total
    };
    assert_ne!(price(1).to_bits(), price(2).to_bits());
}

#[test]
fn stragglers_make_frequent_sync_costlier() {
    // Under heavy-tail stragglers, SyncSGD (a barrier every step) must
    // pay more simulated time than Local SGD (k = 8) for the same step
    // budget — the effect the closed-form span model cannot express.
    let run = |variant: Variant, k1: f64| {
        let mut cfg = ExperimentConfig::default();
        cfg.workload = Workload::LogregTest;
        cfg.engine = "native".into();
        cfg.n_clients = 8;
        cfg.total_steps = 240;
        cfg.cluster = ClusterProfile::heavy_tail_stragglers();
        cfg.algo = AlgoSpec {
            variant,
            eta1: 0.3,
            k1,
            batch: 8,
            ..Default::default()
        };
        workloads::run_experiment(&cfg).unwrap()
    };
    let sync = run(Variant::SyncSgd, 1.0);
    let local = run(Variant::LocalSgd, 8.0);
    assert!(sync.comm.rounds > local.comm.rounds);
    assert!(
        sync.clock.total() > local.clock.total(),
        "sync={} local={}",
        sync.clock.total(),
        local.clock.total()
    );
    // Barrier-wait accounting is populated under heterogeneity.
    assert!(local.timeline.total_max_barrier_wait() > 0.0);
}

//! Flat-arena hot-path bit-identity suite (DESIGN.md §7).
//!
//! PR 5 rebuilt the coordinator loop around a contiguous model arena
//! (allocation-free rounds, in-place collectives, zero-copy threaded
//! dispatch) and gave the simnet engine a heap-free coalesced pricing
//! path. The contract is that none of it changes *what is computed*:
//!
//! * `coordinator::run` (arena) must equal
//!   `coordinator::reference::run_reference` (the pre-arena loop, kept
//!   verbatim) bitwise — every trace point, timeline row, and accounting
//!   total — across cluster preset x participation policy x compressor x
//!   controller x collective;
//! * the threaded engine's zero-copy row dispatch must walk the identical
//!   trajectory;
//! * pricing without a step sink (the coalesced path) must produce
//!   bit-identical `RoundStat`s to pricing with the full event heap.

use std::sync::Arc;
use stl_sgd::algo::{AlgoSpec, ControllerSpec, Variant};
use stl_sgd::comm::{Algorithm, CompressionSchedule};
use stl_sgd::coordinator::{run, run_reference, NativeCompute, RunConfig, ThreadedCompute, Trace};
use stl_sgd::data::{partition, synth, Shard};
use stl_sgd::decentral::ExecMode;
use stl_sgd::grad::logreg::NativeLogreg;
use stl_sgd::rng::Rng;
use stl_sgd::simnet::{ClusterProfile, Detail, ParticipationPolicy};

fn setup(n: usize) -> (Arc<NativeLogreg>, Vec<Shard>) {
    let ds = Arc::new(synth::a9a_like(2, 512, 16));
    let oracle = Arc::new(NativeLogreg::new(ds.clone(), 1e-3));
    let shards = partition::iid(&ds, n, &mut Rng::new(0));
    (oracle, shards)
}

fn spec() -> AlgoSpec {
    // Multi-stage STL-SC: exercises stage anneals, anchor resets, and
    // phase-boundary-truncated rounds.
    AlgoSpec {
        variant: Variant::StlSc,
        eta1: 0.3,
        k1: 4.0,
        t1: 40,
        batch: 8,
        iid: true,
        ..Default::default()
    }
}

fn assert_traces_bitwise(a: &Trace, b: &Trace, tag: &str) {
    assert_eq!(a.points.len(), b.points.len(), "{tag}: point count");
    for (pa, pb) in a.points.iter().zip(&b.points) {
        assert_eq!(pa.iter, pb.iter, "{tag}: iter");
        assert_eq!(pa.rounds, pb.rounds, "{tag}: rounds @ iter {}", pa.iter);
        assert_eq!(pa.epoch.to_bits(), pb.epoch.to_bits(), "{tag}: epoch @ iter {}", pa.iter);
        assert_eq!(pa.loss.to_bits(), pb.loss.to_bits(), "{tag}: loss @ iter {}", pa.iter);
        assert_eq!(
            pa.accuracy.to_bits(),
            pb.accuracy.to_bits(),
            "{tag}: accuracy @ iter {}",
            pa.iter
        );
        assert_eq!(
            pa.sim_seconds.to_bits(),
            pb.sim_seconds.to_bits(),
            "{tag}: sim_seconds @ iter {}",
            pa.iter
        );
        assert_eq!(pa.stage, pb.stage, "{tag}: stage @ iter {}", pa.iter);
        assert_eq!(pa.eta.to_bits(), pb.eta.to_bits(), "{tag}: eta @ iter {}", pa.iter);
        assert_eq!(pa.k, pb.k, "{tag}: k @ iter {}", pa.iter);
        assert_eq!(pa.realized_k, pb.realized_k, "{tag}: realized_k @ iter {}", pa.iter);
    }
    assert_eq!(a.comm, b.comm, "{tag}: comm stats");
    assert_eq!(
        a.clock.compute_seconds.to_bits(),
        b.clock.compute_seconds.to_bits(),
        "{tag}: compute clock"
    );
    assert_eq!(
        a.clock.comm_seconds.to_bits(),
        b.clock.comm_seconds.to_bits(),
        "{tag}: comm clock"
    );
    assert_eq!(a.timeline, b.timeline, "{tag}: timeline");
    assert_eq!(a.total_iters, b.total_iters, "{tag}: total iters");
    assert_eq!(a.stopped_early, b.stopped_early, "{tag}: stop flag");
}

fn run_both(cfg: &RunConfig, tag: &str) {
    let (oracle, shards) = setup(cfg.n_clients);
    let theta0 = vec![0.0f32; 16];
    let phases = spec().phases(240);
    let mut e1 = NativeCompute::new(oracle.clone());
    let arena = run(&mut e1, &shards, &phases, cfg, &theta0, "arena");
    let mut e2 = NativeCompute::new(oracle);
    let legacy = run_reference(&mut e2, &shards, &phases, cfg, &theta0, "arena");
    assert_traces_bitwise(&arena, &legacy, tag);
}

#[test]
fn arena_equals_legacy_identity_on_every_preset_policy_all() {
    // Acceptance gate: `--compressor identity` / policy `all` / every
    // cluster preset is bit-for-bit the pre-PR path under the arena hot
    // path (which is also the coalesced-pricing path: the default detail
    // never attaches a step sink).
    for profile in ClusterProfile::presets() {
        let cfg = RunConfig {
            n_clients: 4,
            profile,
            ..Default::default()
        };
        run_both(&cfg, &format!("identity/all/{}", profile.name));
    }
}

#[test]
fn arena_equals_legacy_across_policies_and_presets() {
    for profile in ClusterProfile::presets() {
        for policy in [ParticipationPolicy::Arrived, ParticipationPolicy::Fraction(0.5)] {
            let cfg = RunConfig {
                n_clients: 4,
                profile,
                participation: policy,
                ..Default::default()
            };
            run_both(&cfg, &format!("identity/{policy:?}/{}", profile.name));
        }
    }
}

#[test]
fn arena_equals_legacy_across_compressors() {
    for profile in [
        ClusterProfile::homogeneous(),
        ClusterProfile::flaky_federated(),
        ClusterProfile::elastic_federated(),
    ] {
        for policy in [ParticipationPolicy::All, ParticipationPolicy::Arrived] {
            for comp in ["topk", "qsgd", "topk-anneal", "qsgd-anneal"] {
                let cfg = RunConfig {
                    n_clients: 4,
                    profile,
                    participation: policy,
                    compression: CompressionSchedule::parse(comp).unwrap(),
                    ..Default::default()
                };
                run_both(&cfg, &format!("{comp}/{policy:?}/{}", profile.name));
            }
        }
    }
}

#[test]
fn arena_equals_legacy_across_controllers_and_collectives() {
    for controller in [
        ControllerSpec::CommRatio { target: 1.0 },
        ControllerSpec::BarrierAware { frac: 0.05 },
    ] {
        for collective in [Algorithm::Naive, Algorithm::Ring, Algorithm::Tree] {
            let cfg = RunConfig {
                n_clients: 6, // non-power-of-two: exercises the tree tail fold
                profile: ClusterProfile::heavy_tail_stragglers(),
                participation: ParticipationPolicy::Arrived,
                collective,
                controller,
                compression: CompressionSchedule::parse("topk").unwrap(),
                ..Default::default()
            };
            run_both(&cfg, &format!("topk/arrived/{controller:?}/{collective:?}"));
        }
    }
}

#[test]
fn bsp_mode_is_the_default_and_pins_the_legacy_path() {
    // PR 6 adds `mode` to RunConfig; `bsp` (the default) must keep every
    // pre-decentral combination bit-for-bit against the reference loop
    // (which has no mode dispatch at all). State the mode explicitly so
    // this pin survives a future Default change.
    assert_eq!(RunConfig::default().mode, ExecMode::Bsp);
    for profile in [
        ClusterProfile::flaky_federated(),
        ClusterProfile::heavy_tail_stragglers(),
    ] {
        for policy in [ParticipationPolicy::All, ParticipationPolicy::Arrived] {
            for comp in ["identity", "topk"] {
                let cfg = RunConfig {
                    n_clients: 4,
                    profile,
                    participation: policy,
                    compression: CompressionSchedule::parse(comp).unwrap(),
                    mode: ExecMode::Bsp,
                    ..Default::default()
                };
                run_both(&cfg, &format!("bsp-mode/{comp}/{policy:?}/{}", profile.name));
            }
        }
    }
}

#[test]
fn arena_equals_legacy_with_step_sink_attached() {
    // Detail::Steps takes the simnet engine down the full heap path in
    // both loops: the coordinator layouts must still agree bitwise, and
    // the recorded event streams must match.
    let cfg = RunConfig {
        n_clients: 4,
        profile: ClusterProfile::elastic_federated(),
        participation: ParticipationPolicy::Arrived,
        timeline_detail: Detail::Steps,
        ..Default::default()
    };
    run_both(&cfg, "identity/arrived/elastic/steps-sink");
}

#[test]
fn threaded_arena_walks_identical_trajectory() {
    // Zero-copy row dispatch vs sequential native, on the arena path,
    // under a masked policy with compression — the full hot path.
    let (oracle, shards) = setup(4);
    let theta0 = vec![0.0f32; 16];
    let phases = spec().phases(240);
    let cfg = RunConfig {
        n_clients: 4,
        profile: ClusterProfile::flaky_federated(),
        participation: ParticipationPolicy::Arrived,
        compression: CompressionSchedule::parse("topk").unwrap(),
        ..Default::default()
    };
    let mut native = NativeCompute::new(oracle.clone());
    let a = run(&mut native, &shards, &phases, &cfg, &theta0, "native");
    let mut threaded = ThreadedCompute::new(oracle, 4);
    let b = run(&mut threaded, &shards, &phases, &cfg, &theta0, "native");
    assert_traces_bitwise(&a, &b, "threaded-vs-native");
}

#[test]
fn coalesced_pricing_equals_heap_pricing_through_the_coordinator() {
    // Same run, only the timeline detail differs: `Rounds` (coalesced
    // pricing, the default) vs `Steps` (full heap). Trajectories, round
    // stats, and clocks must agree bitwise; only the event stream differs.
    for profile in ClusterProfile::presets() {
        let (oracle, shards) = setup(4);
        let theta0 = vec![0.0f32; 16];
        let phases = spec().phases(240);
        let mk = |detail| RunConfig {
            n_clients: 4,
            profile,
            participation: ParticipationPolicy::Arrived,
            timeline_detail: detail,
            ..Default::default()
        };
        let mut e1 = NativeCompute::new(oracle.clone());
        let fast = run(&mut e1, &shards, &phases, &mk(Detail::Rounds), &theta0, "x");
        let mut e2 = NativeCompute::new(oracle);
        let full = run(&mut e2, &shards, &phases, &mk(Detail::Steps), &theta0, "x");
        assert_eq!(fast.points.len(), full.points.len(), "{}", profile.name);
        for (pa, pb) in fast.points.iter().zip(&full.points) {
            assert_eq!(pa.loss.to_bits(), pb.loss.to_bits(), "{} iter {}", profile.name, pa.iter);
            assert_eq!(
                pa.sim_seconds.to_bits(),
                pb.sim_seconds.to_bits(),
                "{} iter {}",
                profile.name,
                pa.iter
            );
        }
        assert_eq!(fast.timeline.rounds, full.timeline.rounds, "{}", profile.name);
        assert!(fast.timeline.events.is_empty(), "no sink -> no events");
        assert!(!full.timeline.events.is_empty(), "sink attached -> events recorded");
        assert_eq!(fast.comm, full.comm, "{}", profile.name);
    }
}

#[test]
fn timeline_off_prices_identically_with_empty_timeline() {
    // Detail::Off (the long-sweep memory fix): same trajectory and
    // clocks, nothing recorded.
    let (oracle, shards) = setup(4);
    let theta0 = vec![0.0f32; 16];
    let phases = spec().phases(240);
    let mk = |detail| RunConfig {
        n_clients: 4,
        profile: ClusterProfile::heavy_tail_stragglers(),
        timeline_detail: detail,
        ..Default::default()
    };
    let mut e1 = NativeCompute::new(oracle.clone());
    let off = run(&mut e1, &shards, &phases, &mk(Detail::Off), &theta0, "x");
    let mut e2 = NativeCompute::new(oracle);
    let rounds = run(&mut e2, &shards, &phases, &mk(Detail::Rounds), &theta0, "x");
    for (pa, pb) in off.points.iter().zip(&rounds.points) {
        assert_eq!(pa.loss.to_bits(), pb.loss.to_bits(), "iter {}", pa.iter);
        assert_eq!(pa.sim_seconds.to_bits(), pb.sim_seconds.to_bits(), "iter {}", pa.iter);
    }
    assert!(off.timeline.rounds.is_empty());
    assert!(off.timeline.events.is_empty());
    assert_eq!(rounds.timeline.rounds.len() as u64, rounds.comm.rounds);
}

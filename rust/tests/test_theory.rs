//! Theory-shape tests: the communication-complexity *orders* the paper
//! proves (Table 3) show up empirically in the schedules and runs.

use stl_sgd::algo::{AlgoSpec, Variant};
use stl_sgd::util::stats::power_law_exponent;

fn spec(variant: Variant, iid: bool) -> AlgoSpec {
    AlgoSpec {
        variant,
        eta1: 1.0,
        alpha: 1e-3,
        k1: 8.0,
        t1: 256,
        batch: 16,
        iid,
        ..Default::default()
    }
}

/// Total comm rounds of the materialized schedule as a function of T.
fn rounds_at(variant: Variant, iid: bool, t: u64) -> f64 {
    spec(variant, iid)
        .phases(t)
        .iter()
        .map(|p| p.comm_rounds())
        .sum::<u64>() as f64
}

#[test]
fn stl_sc_iid_comm_grows_like_log_t() {
    // O(N log T): fitted power-law exponent near 0.
    let ts: Vec<f64> = (4..16).map(|i| 256.0 * ((1u64 << i) - 1) as f64).collect();
    let rounds: Vec<f64> = ts
        .iter()
        .map(|&t| rounds_at(Variant::StlSc, true, t as u64))
        .collect();
    let (p, _) = power_law_exponent(&ts, &rounds);
    assert!(p < 0.2, "exponent {p} (want ~log)");
    // and strictly increasing (it IS growing, just slowly)
    assert!(rounds.windows(2).all(|w| w[1] > w[0]));
}

#[test]
fn stl_sc_noniid_comm_grows_like_sqrt_t() {
    // O(N^1/2 T^1/2): exponent near 0.5.
    let ts: Vec<f64> = (4..16).map(|i| 256.0 * ((1u64 << i) - 1) as f64).collect();
    let rounds: Vec<f64> = ts
        .iter()
        .map(|&t| rounds_at(Variant::StlSc, false, t as u64))
        .collect();
    let (p, r2) = power_law_exponent(&ts, &rounds);
    assert!((p - 0.5).abs() < 0.12, "exponent {p} (want ~0.5), r2={r2}");
}

#[test]
fn local_sgd_comm_grows_linearly_in_t() {
    let ts: Vec<f64> = (10..18).map(|i| (1u64 << i) as f64).collect();
    let rounds: Vec<f64> = ts
        .iter()
        .map(|&t| rounds_at(Variant::LocalSgd, true, t as u64))
        .collect();
    let (p, _) = power_law_exponent(&ts, &rounds);
    assert!((p - 1.0).abs() < 0.05, "exponent {p} (want 1)");
}

#[test]
fn stl_nc2_iid_comm_grows_like_sqrt_t() {
    // Remark 5: sum T_s/k_s = S * T1/k1 with T = T1 S(S+1)/2 => rounds ~
    // T^{1/2}.
    let ts: Vec<f64> = (1..40).map(|s: u64| (256 * s * (s + 1) / 2) as f64).collect();
    let rounds: Vec<f64> = ts
        .iter()
        .map(|&t| rounds_at(Variant::StlNc2, true, t as u64))
        .collect();
    let (p, _) = power_law_exponent(&ts, &rounds);
    assert!((p - 0.5).abs() < 0.1, "exponent {p} (want ~0.5)");
}

#[test]
fn stl_nc2_noniid_comm_grows_like_t_three_quarters() {
    // Remark 5 Non-IID: O(N^{3/4} T^{3/4}).
    let ts: Vec<f64> = (1..40).map(|s: u64| (256 * s * (s + 1) / 2) as f64).collect();
    let rounds: Vec<f64> = ts
        .iter()
        .map(|&t| rounds_at(Variant::StlNc2, false, t as u64))
        .collect();
    let (p, _) = power_law_exponent(&ts, &rounds);
    assert!((p - 0.75).abs() < 0.1, "exponent {p} (want ~0.75)");
}

#[test]
fn sync_sgd_rounds_equal_iterations() {
    for t in [100u64, 1000, 10000] {
        assert_eq!(rounds_at(Variant::SyncSgd, true, t) as u64, t);
    }
}

#[test]
fn stl_sc_total_iterations_double_per_stage() {
    // T_s = 2^{s-1} T_1 (the linear-speedup bookkeeping of Theorem 2).
    let phases = spec(Variant::StlSc, true).phases(256 * ((1 << 8) - 1));
    for (i, w) in phases.windows(2).enumerate() {
        if i + 2 >= phases.len() {
            break;
        }
        assert_eq!(w[1].steps, 2 * w[0].steps, "stage {i}");
    }
}

#[test]
fn linear_speedup_iterations_to_target_shrink_with_n() {
    // Remark 3 linear speedup, measured: more clients reach the gap in
    // fewer iterations (variance reduction through averaging).
    use stl_sgd::bench_support::workloads::{self, compute_f_star};
    use stl_sgd::config::{ExperimentConfig, Workload};

    let f_star = compute_f_star(Workload::LogregTest, 31, 400);
    let gap = 5e-3;
    let iters_for = |n: usize| {
        let cfg = ExperimentConfig {
            workload: Workload::LogregTest,
            iid: true,
            n_clients: n,
            total_steps: 8000,
            seed: 31,
            algo: AlgoSpec {
                variant: Variant::SyncSgd,
                eta1: 0.1, // fixed small lr so variance dominates
                alpha: 0.0,
                batch: 1,
                iid: true,
                ..Default::default()
            },
            collective: stl_sgd::comm::Algorithm::Ring,
            eval_every_rounds: 20,
            engine: "native".into(),
            s_percent: 50.0,
            ..ExperimentConfig::default()
        };
        let trace = workloads::run_experiment(&cfg).unwrap();
        trace
            .points
            .iter()
            .find(|p| p.loss - f_star <= gap)
            .map(|p| p.iter)
    };
    let i1 = iters_for(1);
    let i8 = iters_for(8);
    match (i1, i8) {
        (Some(a), Some(b)) => assert!(b <= a, "N=8 took {b} iters vs N=1 {a}"),
        (None, Some(_)) => {}
        other => panic!("unexpected: {other:?}"),
    }
}

//! Fabric degeneracy + overlap acceptance suite (DESIGN.md §11).
//!
//! The per-link fabric is a *pricing* layer: it must never move a
//! trajectory, and its "off" spelling (`uniform` fabric, `off` overlap)
//! must be bit-for-bit the scalar `NetworkModel` path that every golden
//! and every earlier PR pinned. Four contracts:
//!
//! 1. **Bitwise degeneracy** — a default engine and an engine explicitly
//!    configured `(Uniform, Off, 0)` produce identical timelines and
//!    clocks across preset × mode × collective, and the homogeneous BSP
//!    rounds match the closed-form scalar `allreduce_seconds` exactly.
//! 2. **Pricing invariance** — switching fabrics or enabling overlap
//!    changes *when* rounds finish, never *what* they compute: losses are
//!    bit-identical across every fabric × overlap combination.
//! 3. **Overlap never overcharges** — the chunked pipeline prices every
//!    run prefix no later than the serialized run, and strictly earlier
//!    once any compute is available to hide behind.
//! 4. **Placement matters** — on the rack/WAN matrix the hierarchical
//!    schedule beats the flat ring end to end (the placement_study
//!    example's headline, asserted here so it cannot rot).

use std::sync::Arc;
use stl_sgd::algo::{AlgoSpec, Variant};
use stl_sgd::comm::Algorithm;
use stl_sgd::coordinator::{run, NativeCompute, RunConfig, Trace};
use stl_sgd::data::{partition, synth};
use stl_sgd::decentral::ExecMode;
use stl_sgd::grad::logreg::NativeLogreg;
use stl_sgd::rng::Rng;
use stl_sgd::sim::{ComputeModel, NetworkModel};
use stl_sgd::simnet::{
    ClusterProfile, Detail, LinkFabric, Overlap, ParticipationPolicy, SimNet,
};

fn run_once(cfg: &RunConfig) -> Trace {
    let ds = Arc::new(synth::a9a_like(2, 256, 12));
    let oracle = Arc::new(NativeLogreg::new(ds.clone(), 1e-3));
    let shards = partition::iid(&ds, cfg.n_clients, &mut Rng::new(0));
    let theta0 = vec![0.0f32; 12];
    let spec = AlgoSpec {
        variant: Variant::StlSc,
        eta1: 0.3,
        k1: 5.0,
        t1: 40,
        batch: 8,
        iid: true,
        ..Default::default()
    };
    let phases = spec.phases(150);
    let mut engine = NativeCompute::new(oracle);
    run(&mut engine, &shards, &phases, cfg, &theta0, "stl-sc")
}

fn base_cfg(mode: ExecMode, profile: ClusterProfile, collective: Algorithm) -> RunConfig {
    RunConfig {
        n_clients: 8,
        collective,
        profile,
        mode,
        participation: match mode {
            ExecMode::Bsp => ParticipationPolicy::All,
            _ => ParticipationPolicy::Arrived,
        },
        staleness_bound: 2,
        ..Default::default()
    }
}

// ---------------------------------------------------------------------
// 1. Bitwise degeneracy of the uniform/off spelling.
// ---------------------------------------------------------------------

#[test]
fn uniform_off_is_bitwise_the_scalar_path_across_the_grid() {
    for profile in [ClusterProfile::homogeneous(), ClusterProfile::heavy_tail_stragglers()] {
        for mode in [ExecMode::Bsp, ExecMode::Gossip, ExecMode::BoundedStaleness] {
            for collective in [Algorithm::Naive, Algorithm::Ring, Algorithm::Tree] {
                let legacy = base_cfg(mode, profile, collective);
                let mut explicit = legacy.clone();
                explicit.fabric = LinkFabric::Uniform;
                explicit.overlap = Overlap::Off;
                explicit.chunk_rows = 0;
                let a = run_once(&legacy);
                let b = run_once(&explicit);
                let tag = format!("{mode:?}/{}/{collective:?}", profile.name);
                assert_eq!(a.timeline, b.timeline, "{tag}: timeline");
                assert_eq!(
                    a.to_json().to_string(),
                    b.to_json().to_string(),
                    "{tag}: trace JSON"
                );
                // The degenerate spelling reports dead-flat new columns.
                for rt in &a.timeline.rounds {
                    assert_eq!(rt.overlap_seconds.to_bits(), 0f64.to_bits(), "{tag}");
                    assert_eq!(rt.critical_path_tier, 0, "{tag}");
                }
            }
        }
    }
}

#[test]
fn homogeneous_bsp_rounds_match_the_closed_form_scalar_collective() {
    // Zero-variance profile: every drawn comm span is the base, so each
    // round's comm must be the scalar closed form to the bit.
    let net = NetworkModel::default();
    for alg in [Algorithm::Naive, Algorithm::Ring, Algorithm::Tree] {
        let mut sim = SimNet::new(
            ClusterProfile::homogeneous(),
            net,
            ComputeModel::default(),
            alg,
            8,
            1000,
            7,
            Detail::Rounds,
        )
        .with_fabric(LinkFabric::Uniform, Overlap::Off, 0);
        let rt = sim.price_round(5, 16);
        assert_eq!(
            rt.comm_seconds.to_bits(),
            net.allreduce_seconds(alg, 8, 1000).to_bits(),
            "{alg:?}"
        );
    }
}

// ---------------------------------------------------------------------
// 2. Fabrics and overlap reprice rounds; they never move the trajectory.
// ---------------------------------------------------------------------

#[test]
fn trajectories_are_pricing_invariant_across_fabrics_and_overlap() {
    for mode in [ExecMode::Bsp, ExecMode::Gossip] {
        let mut traces = Vec::new();
        for fabric in ["uniform", "rack-wan:4", "hier:4"] {
            for overlap in [Overlap::Off, Overlap::Chunked] {
                let mut cfg =
                    base_cfg(mode, ClusterProfile::heavy_tail_stragglers(), Algorithm::Ring);
                cfg.fabric = LinkFabric::parse(fabric).unwrap();
                cfg.overlap = overlap;
                traces.push((format!("{fabric}/{}", overlap.label()), run_once(&cfg)));
            }
        }
        let (ref tag0, ref first) = traces[0];
        for (tag, t) in &traces[1..] {
            assert_eq!(
                first.points.len(),
                t.points.len(),
                "{mode:?}: {tag0} vs {tag}"
            );
            for (pa, pb) in first.points.iter().zip(&t.points) {
                assert_eq!(
                    pa.loss.to_bits(),
                    pb.loss.to_bits(),
                    "{mode:?}: loss drift {tag0} vs {tag} @ iter {}",
                    pa.iter
                );
            }
        }
        // ...and the tiered fabric really does reprice the run.
        let uniform_end = first.clock.total();
        let tiered_end = traces[2].1.clock.total();
        assert!(
            (uniform_end - tiered_end).abs() > 1e-9,
            "{mode:?}: rack-wan pricing indistinguishable from uniform"
        );
    }
}

// ---------------------------------------------------------------------
// 3. The overlap model never prices a run *longer* than serialized.
// ---------------------------------------------------------------------

#[test]
fn chunked_overlap_never_exceeds_the_serialized_run() {
    for mode in [ExecMode::Bsp, ExecMode::Gossip] {
        for profile in [ClusterProfile::mild_hetero(), ClusterProfile::heavy_tail_stragglers()] {
            let mut off = base_cfg(mode, profile, Algorithm::Ring);
            off.fabric = LinkFabric::parse("rack-wan:4").unwrap();
            let mut on = off.clone();
            on.overlap = Overlap::Chunked;
            let a = run_once(&off);
            let b = run_once(&on);
            let tag = format!("{mode:?}/{}", profile.name);
            // Same rounds, and every prefix of the pipelined run ends no
            // later than the serialized one.
            assert_eq!(a.timeline.rounds.len(), b.timeline.rounds.len(), "{tag}");
            for (ra, rb) in a.timeline.rounds.iter().zip(&b.timeline.rounds) {
                assert!(
                    rb.end() <= ra.end() + 1e-9,
                    "{tag}: round {} pipelined end {} > serialized {}",
                    ra.round,
                    rb.end(),
                    ra.end()
                );
            }
            assert!(b.clock.total() <= a.clock.total() + 1e-9, "{tag}: run total");
            assert!(
                b.timeline.total_overlap_seconds() > 0.0,
                "{tag}: overlap accounting never credited anything"
            );
        }
    }
}

// ---------------------------------------------------------------------
// 4. Placement: hierarchical beats the flat ring on the tiered fabric.
// ---------------------------------------------------------------------

#[test]
fn hierarchical_placement_beats_flat_ring_end_to_end() {
    let mut flat = base_cfg(ExecMode::Bsp, ClusterProfile::mild_hetero(), Algorithm::Ring);
    flat.fabric = LinkFabric::parse("rack-wan:4").unwrap();
    let mut hier = flat.clone();
    hier.fabric = LinkFabric::parse("hier:4").unwrap();
    let a = run_once(&flat);
    let b = run_once(&hier);
    for (pa, pb) in a.points.iter().zip(&b.points) {
        assert_eq!(pa.loss.to_bits(), pb.loss.to_bits(), "placement moved the trajectory");
    }
    assert!(
        b.clock.total() < a.clock.total(),
        "hierarchical ({:.4}s) should beat the flat ring ({:.4}s) across racks",
        b.clock.total(),
        a.clock.total()
    );
    // The flat run's critical path sits on the WAN tier somewhere.
    assert!(
        a.timeline.rounds.iter().any(|r| r.critical_path_tier == 2),
        "flat placement never reported a WAN-tier critical path"
    );
}

//! Fault-injection, defense, and checkpoint/resume suite (DESIGN.md §12).
//!
//! PR 10's contract has three legs, each pinned here end to end:
//!
//! * **Crash-and-resume bit-identity.** A run killed at round r (right
//!   after its checkpoint) and resumed from the file must produce trace
//!   and timeline CSVs that are *byte-identical* to the uninterrupted
//!   run's — across cluster preset x execution mode x dense/cohort leg x
//!   compressor, with and without active fault plans.
//! * **Honest corruption accounting.** An unclipped run under update
//!   corruption goes non-finite and says so (`poisoned_evals`), while
//!   `clip_norm` keeps the model finite by rejecting/clipping poisoned
//!   rows.
//! * **Neutral knobs are invisible.** Every new knob at its neutral
//!   spelling (faults "none", retry "none", quorum 0, clip_norm 0, plus
//!   an *active* checkpoint writer) leaves the PR-9 trajectory untouched
//!   bit for bit.

use std::sync::Arc;
use stl_sgd::algo::{AlgoSpec, Variant};
use stl_sgd::comm::CompressionSchedule;
use stl_sgd::coordinator::{run, NativeCompute, RunConfig, Trace};
use stl_sgd::data::{partition, synth, Shard};
use stl_sgd::decentral::ExecMode;
use stl_sgd::faults::{FaultPlan, RetryPolicy};
use stl_sgd::grad::logreg::NativeLogreg;
use stl_sgd::rng::Rng;
use stl_sgd::simnet::{ClusterProfile, ParticipationPolicy};

fn setup(n: usize) -> (Arc<NativeLogreg>, Vec<Shard>) {
    let ds = Arc::new(synth::a9a_like(2, 512, 16));
    let oracle = Arc::new(NativeLogreg::new(ds.clone(), 1e-3));
    let shards = partition::iid(&ds, n, &mut Rng::new(0));
    (oracle, shards)
}

fn spec() -> AlgoSpec {
    // Multi-stage STL-SC: anchor resets and phase-truncated rounds make
    // the resume position land both mid-phase and on phase boundaries.
    AlgoSpec {
        variant: Variant::StlSc,
        eta1: 0.3,
        k1: 4.0,
        t1: 40,
        batch: 8,
        iid: true,
        ..Default::default()
    }
}

fn run_one(cfg: &RunConfig) -> Trace {
    let (oracle, shards) = setup(cfg.n_clients);
    let theta0 = vec![0.0f32; 16];
    let phases = spec().phases(240);
    let mut engine = NativeCompute::new(oracle);
    run(&mut engine, &shards, &phases, cfg, &theta0, "x")
}

fn assert_traces_bitwise(a: &Trace, b: &Trace, tag: &str) {
    assert_eq!(a.points.len(), b.points.len(), "{tag}: point count");
    for (pa, pb) in a.points.iter().zip(&b.points) {
        assert_eq!(pa.iter, pb.iter, "{tag}: iter");
        assert_eq!(pa.rounds, pb.rounds, "{tag}: rounds @ iter {}", pa.iter);
        assert_eq!(pa.loss.to_bits(), pb.loss.to_bits(), "{tag}: loss @ iter {}", pa.iter);
        assert_eq!(
            pa.accuracy.to_bits(),
            pb.accuracy.to_bits(),
            "{tag}: accuracy @ iter {}",
            pa.iter
        );
        assert_eq!(
            pa.sim_seconds.to_bits(),
            pb.sim_seconds.to_bits(),
            "{tag}: sim_seconds @ iter {}",
            pa.iter
        );
        assert_eq!(pa.eta.to_bits(), pb.eta.to_bits(), "{tag}: eta @ iter {}", pa.iter);
        assert_eq!(pa.k, pb.k, "{tag}: k @ iter {}", pa.iter);
        assert_eq!(pa.realized_k, pb.realized_k, "{tag}: realized_k @ iter {}", pa.iter);
    }
    assert_eq!(a.comm, b.comm, "{tag}: comm stats");
    assert_eq!(
        a.clock.compute_seconds.to_bits(),
        b.clock.compute_seconds.to_bits(),
        "{tag}: compute clock"
    );
    assert_eq!(
        a.clock.comm_seconds.to_bits(),
        b.clock.comm_seconds.to_bits(),
        "{tag}: comm clock"
    );
    assert_eq!(a.timeline, b.timeline, "{tag}: timeline");
    assert_eq!(a.total_iters, b.total_iters, "{tag}: total iters");
    assert_eq!(a.poisoned_evals, b.poisoned_evals, "{tag}: poisoned evals");
}

/// Run uninterrupted; run again checkpointing and dying at `kill_at`;
/// resume from the file; require byte-identical trace + timeline CSVs.
fn crash_resume_case(tag: &str, cfg: &RunConfig, kill_at: u64) {
    let dir = std::env::temp_dir();
    let stem = format!("stl_faults_{}_{}", std::process::id(), tag);
    let ckpt = dir.join(format!("{stem}.ckpt"));

    let full = run_one(cfg);
    assert!(
        full.comm.rounds > kill_at,
        "{tag}: kill round {kill_at} not inside the {} -round run",
        full.comm.rounds
    );

    let mut killed_cfg = cfg.clone();
    killed_cfg.checkpoint_path = Some(ckpt.clone());
    killed_cfg.kill_at_round = Some(kill_at);
    let killed = run_one(&killed_cfg);
    assert_eq!(killed.comm.rounds, kill_at, "{tag}: died at the wrong round");

    let mut resumed_cfg = cfg.clone();
    resumed_cfg.resume_from = Some(ckpt.clone());
    let resumed = run_one(&resumed_cfg);

    let paths = [
        dir.join(format!("{stem}_full.csv")),
        dir.join(format!("{stem}_resumed.csv")),
        dir.join(format!("{stem}_full_tl.csv")),
        dir.join(format!("{stem}_resumed_tl.csv")),
    ];
    full.write_csv(&paths[0]).unwrap();
    resumed.write_csv(&paths[1]).unwrap();
    full.write_timeline_csv(&paths[2]).unwrap();
    resumed.write_timeline_csv(&paths[3]).unwrap();
    let full_bytes = std::fs::read(&paths[0]).unwrap();
    let resumed_bytes = std::fs::read(&paths[1]).unwrap();
    assert!(full_bytes == resumed_bytes, "{tag}: trace CSVs differ after resume");
    let full_tl = std::fs::read(&paths[2]).unwrap();
    let resumed_tl = std::fs::read(&paths[3]).unwrap();
    assert!(full_tl == resumed_tl, "{tag}: timeline CSVs differ after resume");

    for p in paths.iter().chain(std::iter::once(&ckpt)) {
        let _ = std::fs::remove_file(p);
    }
}

#[test]
fn crash_and_resume_is_bitwise_identical_dense_bsp() {
    crash_resume_case(
        "homog-bsp-dense",
        &RunConfig {
            n_clients: 4,
            ..Default::default()
        },
        5,
    );
    crash_resume_case(
        "flaky-crash-dense",
        &RunConfig {
            n_clients: 4,
            profile: ClusterProfile::flaky_federated(),
            participation: ParticipationPolicy::Arrived,
            faults: FaultPlan::parse("crash=0.15,partition=0.1x2").unwrap(),
            retry: RetryPolicy::parse("retry:2").unwrap(),
            quorum: 0.25,
            ..Default::default()
        },
        7,
    );
}

#[test]
fn crash_and_resume_is_bitwise_identical_compressed() {
    crash_resume_case(
        "topk-crash-dense",
        &RunConfig {
            n_clients: 4,
            profile: ClusterProfile::flaky_federated(),
            participation: ParticipationPolicy::Arrived,
            compression: CompressionSchedule::parse("topk").unwrap(),
            faults: FaultPlan::parse("crash=0.15").unwrap(),
            ..Default::default()
        },
        6,
    );
}

#[test]
fn crash_and_resume_is_bitwise_identical_gossip_and_staleness() {
    crash_resume_case(
        "gossip-ckpt-dense",
        &RunConfig {
            n_clients: 4,
            mode: ExecMode::Gossip,
            ..Default::default()
        },
        5,
    );
    crash_resume_case(
        "stale-crash-dense",
        &RunConfig {
            n_clients: 4,
            profile: ClusterProfile::flaky_federated(),
            participation: ParticipationPolicy::Arrived,
            mode: ExecMode::BoundedStaleness,
            staleness_bound: 2,
            faults: FaultPlan::parse("crash=0.1").unwrap(),
            ..Default::default()
        },
        6,
    );
}

#[test]
fn crash_and_resume_is_bitwise_identical_cohort() {
    crash_resume_case(
        "homog-bsp-cohort",
        &RunConfig {
            n_clients: 4,
            cohort: true,
            ..Default::default()
        },
        5,
    );
    crash_resume_case(
        "flaky-crash-cohort",
        &RunConfig {
            n_clients: 4,
            profile: ClusterProfile::flaky_federated(),
            participation: ParticipationPolicy::Fraction(0.5),
            cohort: true,
            faults: FaultPlan::parse("crash=0.2").unwrap(),
            retry: RetryPolicy::parse("retry").unwrap(),
            quorum: 0.25,
            ..Default::default()
        },
        7,
    );
}

#[test]
fn corruption_unclipped_poisons_clipped_stays_finite() {
    let base = RunConfig {
        n_clients: 4,
        faults: FaultPlan::parse("corrupt=0.5").unwrap(),
        ..Default::default()
    };
    let poisoned = run_one(&base);
    assert!(
        poisoned.poisoned_evals > 0,
        "heavy NaN/Inf corruption never reached an eval"
    );
    assert!(
        !poisoned.final_loss().is_finite(),
        "undefended corruption should leave the model non-finite"
    );

    let mut defended = base.clone();
    defended.clip_norm = 5.0;
    let survived = run_one(&defended);
    assert_eq!(
        survived.poisoned_evals, 0,
        "clip_norm let a poisoned row into the average"
    );
    assert!(survived.final_loss().is_finite());
    assert!(
        survived.timeline.total_corrupt_dropped() > 0,
        "no non-finite corruption was even drawn — the scenario is vacuous"
    );
}

#[test]
fn retry_reduces_abandoned_rounds() {
    let base = RunConfig {
        n_clients: 4,
        faults: FaultPlan::parse("crash=0.4").unwrap(),
        quorum: 0.75,
        ..Default::default()
    };
    let without = run_one(&base);
    assert!(
        without.timeline.total_abandoned() > 0,
        "crash=0.4 under quorum 0.75 never abandoned a round"
    );
    let mut with_retry = base.clone();
    with_retry.retry = RetryPolicy::parse("retry:3").unwrap();
    let with = run_one(&with_retry);
    assert!(with.timeline.total_retries() > 0, "the retry policy never fired");
    assert!(
        with.timeline.total_abandoned() < without.timeline.total_abandoned(),
        "retries ({}) did not reduce abandoned rounds ({} vs {})",
        with.timeline.total_retries(),
        with.timeline.total_abandoned(),
        without.timeline.total_abandoned()
    );
    // Both stay trainable: abandoned rounds roll back, they don't poison.
    assert!(without.final_loss().is_finite());
    assert!(with.final_loss().is_finite());
}

#[test]
fn neutral_knobs_are_bitwise_invisible() {
    // Matrix leg: (profile, mode, compressor, participation, cohort).
    let cases: Vec<(&str, RunConfig)> = vec![
        (
            "bsp-identity-arrived",
            RunConfig {
                n_clients: 4,
                participation: ParticipationPolicy::Arrived,
                profile: ClusterProfile::flaky_federated(),
                ..Default::default()
            },
        ),
        (
            "bsp-topk-frac",
            RunConfig {
                n_clients: 4,
                participation: ParticipationPolicy::Fraction(0.5),
                profile: ClusterProfile::heavy_tail_stragglers(),
                compression: CompressionSchedule::parse("topk").unwrap(),
                ..Default::default()
            },
        ),
        (
            "gossip-identity",
            RunConfig {
                n_clients: 4,
                mode: ExecMode::Gossip,
                ..Default::default()
            },
        ),
        (
            "stale-identity-arrived",
            RunConfig {
                n_clients: 4,
                mode: ExecMode::BoundedStaleness,
                staleness_bound: 2,
                participation: ParticipationPolicy::Arrived,
                profile: ClusterProfile::flaky_federated(),
                ..Default::default()
            },
        ),
        (
            "cohort-topk-frac",
            RunConfig {
                n_clients: 4,
                cohort: true,
                participation: ParticipationPolicy::Fraction(0.5),
                profile: ClusterProfile::flaky_federated(),
                compression: CompressionSchedule::parse("topk").unwrap(),
                ..Default::default()
            },
        ),
    ];
    for (tag, base) in cases {
        let reference = run_one(&base);
        let ckpt = std::env::temp_dir()
            .join(format!("stl_neutral_{}_{}.ckpt", std::process::id(), tag));
        let mut neutral = base.clone();
        // The neutral spellings, routed through the same parsers the
        // config layer uses — plus a live checkpoint writer, which must
        // observe the run without perturbing it.
        neutral.faults = FaultPlan::parse("none").unwrap();
        neutral.retry = RetryPolicy::parse("none").unwrap();
        neutral.quorum = 0.0;
        neutral.clip_norm = 0.0;
        neutral.checkpoint_path = Some(ckpt.clone());
        let knobby = run_one(&neutral);
        assert_traces_bitwise(&reference, &knobby, tag);
        let _ = std::fs::remove_file(&ckpt);
    }
}
